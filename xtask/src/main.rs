//! Repo lint gate — `cargo run -p xtask -- check`.
//!
//! A std-only scanner (no `syn`: nothing to vendor in this offline
//! environment) that walks `rust/` and `examples/` through a
//! comment/string-aware mini-lexer and enforces the concurrency
//! invariants the analysis tooling rests on:
//!
//! 1. **SAFETY comments.**  Every `unsafe` block and `unsafe impl`
//!    must be immediately preceded by (or share a line with) a comment
//!    containing `SAFETY:`.  `unsafe fn` signatures are exempt: the
//!    crate-wide `#![deny(unsafe_op_in_unsafe_fn)]` (also asserted
//!    here) forces their bodies into explicit `unsafe { }` blocks,
//!    which the rule does cover.
//! 2. **Thread confinement.**  `thread::spawn` / `thread::scope` /
//!    `thread::Builder` appear only in `util/sync.rs` and
//!    `sparse/par.rs`, so every OS thread is created through the
//!    loom-switchable shim and the loom models stay a faithful
//!    abstraction of the process's concurrency.
//! 3. **Kernel purity.**  No `Instant::now` under `rust/src/sparse/`
//!    — kernels stay deterministic and timing-free; measurement
//!    belongs to the bench harness and the serving loop.
//! 4. **Shim confinement.**  Loom-modeled modules (the serve admission
//!    queue) name no `std::sync::{Mutex, Condvar, MutexGuard}`
//!    directly — they go through the `util::sync` shim, so the loom
//!    model checks the exact synchronization the release build runs.
//! 5. **Panic-recovery confinement.**  `catch_unwind` appears only at
//!    the audited recovery boundaries: the kernel worker pool
//!    (`sparse/par.rs`, which re-raises on the submitting thread), the
//!    shard supervisor (`serve/engine.rs`, which fails in-flight
//!    requests and restarts the shard), and the failpoint unit tests
//!    (`util/failpoint.rs`, which assert injected faults unwind).
//!    Anywhere else, swallowing a panic hides bugs.
//!
//! Prints the full `unsafe` inventory either way; exits non-zero with
//! a violation list when the gate fails.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("check") => check(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- check");
            ExitCode::from(2)
        }
    }
}

struct Violation {
    file: String,
    line: usize,
    msg: String,
}

struct UnsafeSite {
    file: String,
    line: usize,
    kind: &'static str,
    safety: Option<String>,
}

fn check() -> ExitCode {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives directly under the repo root")
        .to_path_buf();
    let mut files = Vec::new();
    for dir in ["rust", "examples"] {
        collect_rs(&root.join(dir), &mut files);
    }
    files.sort();

    let mut violations = Vec::new();
    let mut inventory = Vec::new();
    for path in &files {
        let rel = rel_path(&root, path);
        let src = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                violations.push(Violation {
                    file: rel,
                    line: 0,
                    msg: format!("unreadable: {e}"),
                });
                continue;
            }
        };
        let lines = lex(&src);
        scan_unsafe(&rel, &lines, &mut inventory, &mut violations);
        scan_threads(&rel, &lines, &mut violations);
        scan_kernel_purity(&rel, &lines, &mut violations);
        scan_sync_shim(&rel, &lines, &mut violations);
        scan_catch_unwind(&rel, &lines, &mut violations);
    }
    check_deny_attr(&root, &mut violations);

    println!("xtask check: {} files scanned", files.len());
    print_inventory(&inventory);
    if violations.is_empty() {
        println!("ok: zero violations");
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("violation: {}:{}: {}", v.file, v.line, v.msg);
        }
        eprintln!("{} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == "target") {
                continue;
            }
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

// ---------------------------------------------------------------------
// Mini-lexer
// ---------------------------------------------------------------------

/// One physical source line: `code` with comments removed and
/// string/char-literal contents blanked, plus the line's comment text
/// (kept verbatim so the SAFETY rule can read it).
struct Line {
    code: String,
    comment: String,
}

fn flush(lines: &mut Vec<Line>, code: &mut String, comment: &mut String) {
    lines.push(Line {
        code: std::mem::take(code),
        comment: std::mem::take(comment),
    });
}

/// Split `src` into [`Line`]s, handling line comments, nested block
/// comments, string literals (with escapes), raw strings
/// (`r"…"` / `r#"…"#`), and char-vs-lifetime disambiguation.
fn lex(src: &str) -> Vec<Line> {
    let b = src.as_bytes();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'\n' => {
                flush(&mut lines, &mut code, &mut comment);
                i += 1;
            }
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                comment.push_str(&src[start..i]);
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let mut depth = 1u32;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        if b[i] == b'\n' {
                            flush(&mut lines, &mut code, &mut comment);
                        }
                        i += 1;
                    }
                }
            }
            b'"' => {
                code.push_str("\"\"");
                i += 1;
                while i < b.len() {
                    match b[i] {
                        b'\\' => i += 2,
                        b'"' => {
                            i += 1;
                            break;
                        }
                        b'\n' => {
                            flush(&mut lines, &mut code, &mut comment);
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
            }
            b'r' if !ends_in_ident(&code) => {
                if let Some(hashes) = raw_string_hashes(b, i + 1) {
                    code.push_str("r\"\"");
                    i += 2 + hashes; // past `r`, the `#`s and the quote
                    while i < b.len() {
                        if b[i] == b'"' && closes_raw(b, i + 1, hashes) {
                            i += 1 + hashes;
                            break;
                        }
                        if b[i] == b'\n' {
                            flush(&mut lines, &mut code, &mut comment);
                        }
                        i += 1;
                    }
                } else {
                    code.push('r');
                    i += 1;
                }
            }
            b'\'' => {
                if b.get(i + 1) == Some(&b'\\') {
                    i += 3; // past `'`, `\` and the escaped byte
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i += 1;
                    code.push_str("''");
                } else if b.get(i + 2) == Some(&b'\'')
                    && b.get(i + 1) != Some(&b'\'')
                {
                    code.push_str("''"); // plain char literal
                    i += 3;
                } else {
                    code.push('\''); // lifetime or loop label
                    i += 1;
                }
            }
            c if c.is_ascii() => {
                code.push(c as char);
                i += 1;
            }
            // non-ASCII code bytes can't be part of any rule token
            _ => i += 1,
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        flush(&mut lines, &mut code, &mut comment);
    }
    lines
}

fn ends_in_ident(code: &str) -> bool {
    code.chars()
        .next_back()
        .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Does `b[j..]` read `#*"` — the tail of a raw-string opener?
fn raw_string_hashes(b: &[u8], j: usize) -> Option<usize> {
    let mut h = 0;
    while b.get(j + h) == Some(&b'#') {
        h += 1;
    }
    (b.get(j + h) == Some(&b'"')).then_some(h)
}

/// Does `b[j..]` hold the `hashes` `#`s that close a raw string?
fn closes_raw(b: &[u8], j: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| b.get(j + k) == Some(&b'#'))
}

/// Byte offsets of standalone occurrences of `word` in `hay` (not
/// embedded inside a longer identifier).
fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let ident = |c: char| c.is_ascii_alphanumeric() || c == '_';
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let before = hay[..at].chars().next_back();
        let after = hay[at + word.len()..].chars().next();
        if !before.is_some_and(ident) && !after.is_some_and(ident) {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

// ---------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------

fn scan_unsafe(
    file: &str,
    lines: &[Line],
    inventory: &mut Vec<UnsafeSite>,
    violations: &mut Vec<Violation>,
) {
    for (li, line) in lines.iter().enumerate() {
        for col in find_word(&line.code, "unsafe") {
            let kind = classify(lines, li, col + "unsafe".len());
            let safety = safety_comment(lines, li);
            if kind != "fn" && safety.is_none() {
                violations.push(Violation {
                    file: file.to_string(),
                    line: li + 1,
                    msg: format!(
                        "`unsafe {kind}` without a `// SAFETY:` comment \
                         immediately above (or on the same line)"
                    ),
                });
            }
            inventory.push(UnsafeSite {
                file: file.to_string(),
                line: li + 1,
                kind,
                safety,
            });
        }
    }
}

/// The token following an `unsafe` keyword (possibly on a later line):
/// `impl`, `fn` (signature or fn-pointer type — exempt), `extern`,
/// `block`, or `?` when nothing parsable follows.
fn classify(lines: &[Line], li: usize, after: usize) -> &'static str {
    let mut rest = lines[li].code[after..].to_string();
    let mut j = li + 1;
    while rest.trim().is_empty() && j < lines.len() {
        rest.clone_from(&lines[j].code);
        j += 1;
    }
    let rest = rest.trim_start();
    if rest.starts_with("impl") {
        "impl"
    } else if rest.starts_with("fn") {
        "fn"
    } else if rest.starts_with("extern") {
        "extern"
    } else if rest.starts_with('{') {
        "block"
    } else {
        "?"
    }
}

/// The `SAFETY:` text attached to line `li`: on the line itself or in
/// the contiguous run of comment-only lines directly above it.
fn safety_comment(lines: &[Line], li: usize) -> Option<String> {
    if let Some(s) = extract_safety(&lines[li].comment) {
        return Some(s);
    }
    let mut j = li;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        if !l.code.trim().is_empty() || l.comment.trim().is_empty() {
            break;
        }
        if let Some(s) = extract_safety(&l.comment) {
            return Some(s);
        }
    }
    None
}

fn extract_safety(comment: &str) -> Option<String> {
    comment.find("SAFETY:").map(|p| {
        let tail = comment[p + "SAFETY:".len()..].trim();
        let mut s: String = tail.chars().take(60).collect();
        if tail.chars().count() > 60 {
            s.push('…');
        }
        s
    })
}

const THREAD_ALLOWED: [&str; 2] =
    ["rust/src/util/sync.rs", "rust/src/sparse/par.rs"];
const THREAD_TOKENS: [&str; 3] =
    ["thread::spawn", "thread::scope", "thread::Builder"];

fn scan_threads(file: &str, lines: &[Line], violations: &mut Vec<Violation>) {
    if THREAD_ALLOWED.contains(&file) {
        return;
    }
    for (li, line) in lines.iter().enumerate() {
        for tok in THREAD_TOKENS {
            if line.code.contains(tok) {
                violations.push(Violation {
                    file: file.to_string(),
                    line: li + 1,
                    msg: format!(
                        "`{tok}` outside util/sync.rs / sparse/par.rs — \
                         spawn through `util::sync::spawn_named` so the \
                         loom models stay faithful"
                    ),
                });
            }
        }
    }
}

fn scan_kernel_purity(
    file: &str,
    lines: &[Line],
    violations: &mut Vec<Violation>,
) {
    if !file.starts_with("rust/src/sparse/") {
        return;
    }
    for (li, line) in lines.iter().enumerate() {
        if line.code.contains("Instant::now") {
            violations.push(Violation {
                file: file.to_string(),
                line: li + 1,
                msg: "`Instant::now` inside a kernel module — timing \
                      belongs to the bench harness / serving loop"
                    .to_string(),
            });
        }
    }
}

/// Files whose locks are loom-model-checked: they must name the
/// `util::sync` shim types only, never `std::sync` sync primitives
/// directly — a direct `std::sync::Mutex` would compile under loom but
/// sit outside the model, silently unchecked.
const SYNC_SHIM_CONFINED: [&str; 1] = ["rust/src/serve/admission.rs"];
const SYNC_STD_TOKENS: [&str; 3] = [
    "std::sync::Mutex",
    "std::sync::Condvar",
    "std::sync::MutexGuard",
];

fn scan_sync_shim(
    file: &str,
    lines: &[Line],
    violations: &mut Vec<Violation>,
) {
    if !SYNC_SHIM_CONFINED.contains(&file) {
        return;
    }
    for (li, line) in lines.iter().enumerate() {
        for tok in SYNC_STD_TOKENS {
            if line.code.contains(tok) {
                violations.push(Violation {
                    file: file.to_string(),
                    line: li + 1,
                    msg: format!(
                        "`{tok}` in a loom-modeled module — use the \
                         `util::sync` shim so the loom model checks \
                         the synchronization the release build runs"
                    ),
                });
            }
        }
    }
}

/// Panic-recovery boundaries are deliberate, audited design points —
/// each allowlisted file either re-raises (the worker pool hands the
/// payload back to the submitting shard thread), compensates (the
/// shard supervisor fails every in-flight request and restarts the
/// shard on a fresh pool), or is a test asserting that an injected
/// fault really unwinds.  A `catch_unwind` anywhere else is almost
/// certainly a bug being swallowed.
const CATCH_UNWIND_ALLOWED: [&str; 3] = [
    "rust/src/sparse/par.rs",
    "rust/src/serve/engine.rs",
    "rust/src/util/failpoint.rs",
];

fn scan_catch_unwind(
    file: &str,
    lines: &[Line],
    violations: &mut Vec<Violation>,
) {
    if CATCH_UNWIND_ALLOWED.contains(&file) {
        return;
    }
    for (li, line) in lines.iter().enumerate() {
        if line.code.contains("catch_unwind") {
            violations.push(Violation {
                file: file.to_string(),
                line: li + 1,
                msg: "`catch_unwind` outside the audited recovery \
                      boundaries (sparse/par.rs worker pool, \
                      serve/engine.rs shard supervisor, \
                      util/failpoint.rs tests) — recover or re-raise \
                      there, never swallow panics elsewhere"
                    .to_string(),
            });
        }
    }
}

fn check_deny_attr(root: &Path, violations: &mut Vec<Violation>) {
    let lib = root.join("rust/src/lib.rs");
    let ok = std::fs::read_to_string(&lib)
        .map(|src| {
            lex(&src)
                .iter()
                .any(|l| l.code.contains("deny(unsafe_op_in_unsafe_fn)"))
        })
        .unwrap_or(false);
    if !ok {
        violations.push(Violation {
            file: "rust/src/lib.rs".to_string(),
            line: 1,
            msg: "missing crate-wide `#![deny(unsafe_op_in_unsafe_fn)]`"
                .to_string(),
        });
    }
}

fn print_inventory(inventory: &[UnsafeSite]) {
    let exempt = inventory.iter().filter(|s| s.kind == "fn").count();
    println!(
        "unsafe inventory: {} sites ({} `unsafe fn` signatures / \
         fn-pointer types, exempt from the comment rule):",
        inventory.len(),
        exempt
    );
    for s in inventory {
        let mut row = format!("  {}:{} {}", s.file, s.line, s.kind);
        if let Some(sfty) = &s.safety {
            let _ = write!(row, " — SAFETY: {sfty}");
        }
        println!("{row}");
    }
}
