"""Pallas kernels for the TwELL sparse format (paper section 3, alg. 1+2).

TPU adaptation of the paper's H100 CUDA kernels (DESIGN.md section
"Hardware adaptation"):

  * Algorithm 1 (`twell_gate_matmul`): a tiled matmul over (T_m, T_n)
    output blocks — on TPU each block is a VMEM-resident tile produced by
    the MXU — whose *epilogue* applies ReLU and packs the block into the
    TwELL layout before it is written back to HBM.  The CUDA version does
    the pack with a CTA-scoped atomic counter on the WGMMA register
    fragment; the TPU/VPU version does the equivalent with a per-row
    prefix-sum (cumsum) over the non-zero mask, which is the natural
    vector-unit rendering of the same "local non-zero count" (alg. 1,
    lines 8-15).
  * Algorithm 2 (`twell_fused_ffn`): consumes the TwELL gate activations
    and fuses the up and down projections, touching only the W_u columns /
    W_d rows named by the packed indices (eq. 3).

All kernels run with `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness path and the
rust CPU kernels (`rust/src/sparse/`) are the performance path.  Estimated
VMEM footprint / MXU utilization for a real TPU are derived from the
BlockSpecs in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Pallas interpret mode is mandatory here — see module docstring.
INTERPRET = True


# ---------------------------------------------------------------------------
# Algorithm 1: tiled gate matmul with TwELL pack in the epilogue
# ---------------------------------------------------------------------------

def _gate_pack_kernel(x_ref, wg_ref, hv_ref, hi_ref, hnz_ref, *, tile_n, comp):
    """One (T_m, T_n) output tile: MXU matmul + ReLU + TwELL pack epilogue."""
    j = pl.program_id(1)
    slots = tile_n // comp
    # matmul for this tile (f32 accumulation, as the paper's WGMMA does)
    s = jnp.maximum(
        jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32),
        0.0,
    )  # (T_m, T_n)
    mask = s > 0.0
    # per-row running non-zero count (alg. 1 line 8/15) as a prefix sum
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1  # (T_m, T_n)
    # destination slot; invalid or overflowing entries land on `slots`,
    # which the scatter drops (paper: overflow is made "practically
    # impossible" by a conservative C; we drop-and-count like the kernels)
    dest = jnp.where(mask, jnp.minimum(pos, slots), slots)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + j * tile_n
    hv = jnp.zeros((s.shape[0], slots), jnp.float32)
    hi = jnp.zeros((s.shape[0], slots), jnp.int32)
    hv_ref[...] = hv.at[rows, dest].set(s, mode="drop")
    hi_ref[...] = hi.at[rows, dest].set(cols, mode="drop")
    hnz_ref[...] = jnp.minimum(
        mask.astype(jnp.int32).sum(axis=1, keepdims=True), slots
    )


def twell_gate_matmul(x, wg, *, tile_n=32, comp=4, tile_m=8):
    """h_g = ReLU(x @ Wg) materialized directly in TwELL (algorithm 1).

    Returns (h_v f32[M, N//C], h_i i32[M, N//C], h_nz i32[M, N//T]).
    """
    m_dim, k_dim = x.shape
    k2, n_dim = wg.shape
    assert k_dim == k2
    assert n_dim % tile_n == 0 and m_dim % tile_m == 0
    assert tile_n % comp == 0
    slots = tile_n // comp
    n_tiles = n_dim // tile_n
    grid = (m_dim // tile_m, n_tiles)
    return pl.pallas_call(
        functools.partial(_gate_pack_kernel, tile_n=tile_n, comp=comp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((k_dim, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, slots), lambda i, j: (i, j)),
            pl.BlockSpec((tile_m, slots), lambda i, j: (i, j)),
            pl.BlockSpec((tile_m, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, n_dim // comp), jnp.float32),
            jax.ShapeDtypeStruct((m_dim, n_dim // comp), jnp.int32),
            jax.ShapeDtypeStruct((m_dim, n_tiles), jnp.int32),
        ],
        interpret=INTERPRET,
    )(x, wg)


# ---------------------------------------------------------------------------
# Algorithm 2: fused up + down projection from TwELL gate activations
# ---------------------------------------------------------------------------

def _fused_kernel(
    x_ref, hv_ref, hi_ref, hnz_ref, wu_ref, wd_ref, y_ref, *, tile_n, comp
):
    """One block of rows: eq. (3) — gather W_u columns / W_d rows named by
    the packed indices, implicit h_u materialization in-register."""
    slots = tile_n // comp
    x = x_ref[...]                      # (T_m, K)
    hv = hv_ref[...]                    # (T_m, NC)
    hi = hi_ref[...]                    # (T_m, NC)
    hnz = hnz_ref[...]                  # (T_m, N_T)
    wu = wu_ref[...]                    # (K, N)
    wd = wd_ref[...]                    # (N, K)
    nc = hv.shape[1]
    slot = jax.lax.broadcasted_iota(jnp.int32, hv.shape, 1)
    tile_of_slot = slot // slots
    col_in_tile = slot % slots
    valid = col_in_tile < jnp.take_along_axis(hnz, tile_of_slot, axis=1)
    # u[m, j] = x[m, :] . W_u[:, n(m, j)]   (the implicit h_u element)
    wu_g = jnp.take(wu.T, hi, axis=0)   # (T_m, NC, K)
    u = jnp.einsum("mk,mjk->mj", x, wu_g)
    coeff = jnp.where(valid, hv * u, 0.0)          # h_v * h_u
    wd_g = jnp.take(wd, hi, axis=0)     # (T_m, NC, K)
    y_ref[...] = jnp.einsum("mj,mjk->mk", coeff, wd_g)


def twell_fused_ffn(x, h_v, h_i, h_nz, wu, wd, *, tile_n=32, comp=4, tile_m=8):
    """y = ((h_g in TwELL) * (x @ Wu)) @ Wd in one fused kernel (alg. 2)."""
    m_dim, k_dim = x.shape
    n_dim = wu.shape[1]
    nc = h_v.shape[1]
    n_tiles = h_nz.shape[1]
    grid = (m_dim // tile_m,)
    return pl.pallas_call(
        functools.partial(_fused_kernel, tile_n=tile_n, comp=comp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, n_tiles), lambda i: (i, 0)),
            pl.BlockSpec((k_dim, n_dim), lambda i: (0, 0)),
            pl.BlockSpec((n_dim, k_dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, k_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), jnp.float32),
        interpret=INTERPRET,
    )(x, h_v, h_i, h_nz, wu, wd)


# ---------------------------------------------------------------------------
# Non-gated variant: down projection alone from TwELL (appendix A.1)
# ---------------------------------------------------------------------------

def _down_kernel(hv_ref, hi_ref, hnz_ref, wd_ref, y_ref, *, tile_n, comp):
    slots = tile_n // comp
    hv = hv_ref[...]
    hi = hi_ref[...]
    hnz = hnz_ref[...]
    wd = wd_ref[...]
    slot = jax.lax.broadcasted_iota(jnp.int32, hv.shape, 1)
    valid = (slot % slots) < jnp.take_along_axis(hnz, slot // slots, axis=1)
    coeff = jnp.where(valid, hv, 0.0)
    wd_g = jnp.take(wd, hi, axis=0)     # (T_m, NC, K)
    y_ref[...] = jnp.einsum("mj,mjk->mk", coeff, wd_g)


def twell_down_matmul(h_v, h_i, h_nz, wd, *, tile_n=32, comp=4, tile_m=8):
    """y = (h_u in TwELL) @ Wd — non-gated model's second projection."""
    m_dim, nc = h_v.shape
    n_dim, k_dim = wd.shape
    n_tiles = h_nz.shape[1]
    grid = (m_dim // tile_m,)
    return pl.pallas_call(
        functools.partial(_down_kernel, tile_n=tile_n, comp=comp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, n_tiles), lambda i: (i, 0)),
            pl.BlockSpec((n_dim, k_dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, k_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), jnp.float32),
        interpret=INTERPRET,
    )(h_v, h_i, h_nz, wd)


# ---------------------------------------------------------------------------
# Whole-block convenience wrappers (used by model.py and the AOT demo)
# ---------------------------------------------------------------------------

def gated_ffn_twell(x, wg, wu, wd, *, tile_n=32, comp=4, tile_m=8):
    """Full gated FFN through the two-kernel sparse pipeline (section 3.3)."""
    h_v, h_i, h_nz = twell_gate_matmul(
        x, wg, tile_n=tile_n, comp=comp, tile_m=tile_m
    )
    return twell_fused_ffn(
        x, h_v, h_i, h_nz, wu, wd, tile_n=tile_n, comp=comp, tile_m=tile_m
    )


def nongated_ffn_twell(x, wu, wd, *, tile_n=32, comp=4, tile_m=8):
    """Non-gated FFN: up projection w/ TwELL store, then sparse down."""
    h_v, h_i, h_nz = twell_gate_matmul(
        x, wu, tile_n=tile_n, comp=comp, tile_m=tile_m
    )
    return twell_down_matmul(
        h_v, h_i, h_nz, wd, tile_n=tile_n, comp=comp, tile_m=tile_m
    )
