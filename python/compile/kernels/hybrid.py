"""Hybrid ELL+dense training format (paper section 3.4/3.5, listing 4).

The hybrid format dynamically routes each activation row either into an
aggressively compact ELL matrix (nnz <= ELL_WIDTH) or a dense backup tail.
This module provides:

  * `twell_to_hybrid_kernel` — a Pallas kernel mirroring listing 4: one
    program per row block, an intra-row prefix scan over the per-tile
    non-zero counts to compact TwELL tiles into contiguous ELL storage,
    plus L0/L1 statistics accumulation.
  * jnp-level hybrid ops (`hybrid_matmul`, `dense_to_hybrid_matmul`) with
    fixed shapes, used by model-level tests; the throughput-bearing
    implementations live in rust/src/sparse/hybrid.rs.

Because XLA requires static shapes, the dense tail has a fixed capacity
(max_dense_rows) and routing is expressed with masks; the semantics
(including drop-and-flag on overflow, appendix B.2.1) exactly match the
reference in ref.py and the rust implementation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


# ---------------------------------------------------------------------------
# TwELL -> ELL compaction (listing 4's core, as a Pallas kernel)
# ---------------------------------------------------------------------------

def _twell_to_ell_kernel(
    hv_ref, hi_ref, hnz_ref, ev_ref, ec_ref, rn_ref, l0_ref, l1_ref,
    *, tile_n, comp, ell_width,
):
    """Compact a block of TwELL rows into contiguous ELL rows.

    CUDA listing 4 gives one warp per row and uses __shfl_up prefix scans;
    the vector-unit rendering is an exclusive cumsum over per-tile counts.
    """
    slots = tile_n // comp
    hv = hv_ref[...]                  # (T_m, NC)
    hi = hi_ref[...]
    hnz = hnz_ref[...]                # (T_m, N_T)
    # exclusive prefix over tile counts = start offset of each tile's data
    start = jnp.cumsum(hnz, axis=1) - hnz            # (T_m, N_T)
    slot = jax.lax.broadcasted_iota(jnp.int32, hv.shape, 1)
    t = slot // slots
    c = slot % slots
    valid = c < jnp.take_along_axis(hnz, t, axis=1)
    dest = jnp.take_along_axis(start, t, axis=1) + c  # target ELL column
    # invalid or beyond-ELL_WIDTH entries are dropped (overflow rows are
    # promoted to the dense tail by the caller; see hybrid_partition)
    dest = jnp.where(valid & (dest < ell_width), dest, ell_width)
    rows = jax.lax.broadcasted_iota(jnp.int32, hv.shape, 0)
    ev = jnp.zeros((hv.shape[0], ell_width), jnp.float32)
    ec = jnp.zeros((hv.shape[0], ell_width), jnp.int32)
    ev_ref[...] = ev.at[rows, dest].set(hv, mode="drop")
    ec_ref[...] = ec.at[rows, dest].set(hi, mode="drop")
    total = hnz.sum(axis=1, keepdims=True)
    rn_ref[...] = total                # true occupancy, even when > width
    # L0/L1 statistics (listing 4 accumulates these for the training loss)
    l0_ref[...] = total.astype(jnp.float32)
    l1_ref[...] = jnp.where(valid, hv, 0.0).sum(axis=1, keepdims=True)


def twell_to_ell(h_v, h_i, h_nz, *, tile_n=32, comp=4, ell_width=128,
                 tile_m=8):
    """Compact TwELL storage into fixed-width ELL rows + stats.

    Returns (ell_val f32[M,W], ell_col i32[M,W], row_nnz i32[M,1],
    l0 f32[M,1], l1 f32[M,1]).  row_nnz holds the *true* count so callers
    can detect rows needing dense-tail promotion (row_nnz > W).
    """
    m_dim, nc = h_v.shape
    n_tiles = h_nz.shape[1]
    grid = (m_dim // tile_m,)
    return pl.pallas_call(
        functools.partial(
            _twell_to_ell_kernel, tile_n=tile_n, comp=comp,
            ell_width=ell_width,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, nc), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, n_tiles), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_m, ell_width), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, ell_width), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_m, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, ell_width), jnp.float32),
            jax.ShapeDtypeStruct((m_dim, ell_width), jnp.int32),
            jax.ShapeDtypeStruct((m_dim, 1), jnp.int32),
            jax.ShapeDtypeStruct((m_dim, 1), jnp.float32),
            jax.ShapeDtypeStruct((m_dim, 1), jnp.float32),
        ],
        interpret=INTERPRET,
    )(h_v, h_i, h_nz)


# ---------------------------------------------------------------------------
# jnp-level hybrid operations (static-shape renderings of algorithm 3)
# ---------------------------------------------------------------------------

def hybrid_partition(h, *, ell_width=128, max_dense_rows=None):
    """Dense (M, N) -> hybrid dict with fixed shapes.

    Pure jnp version of the routing rule; matches
    ref.hybrid_partition_slow bit-for-bit on the ELL component, and stores
    overflow rows in a fixed-capacity dense tail addressed by a rank
    computed with a cumulative sum (the jnp rendering of
    get_or_allocate_dense_row from listing 7).
    """
    m_dim, n_dim = h.shape
    if max_dense_rows is None:
        max_dense_rows = max(1, m_dim // 8)
    nz = h != 0.0
    row_nnz = nz.sum(axis=1).astype(jnp.int32)
    is_dense = row_nnz > ell_width
    # ELL compaction for sparse rows
    pos = jnp.cumsum(nz.astype(jnp.int32), axis=1) - 1
    dest = jnp.where(nz & ~is_dense[:, None], jnp.minimum(pos, ell_width), ell_width)
    rows = jax.lax.broadcasted_iota(jnp.int32, h.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, h.shape, 1)
    ell_val = jnp.zeros((m_dim, ell_width), h.dtype).at[rows, dest].set(
        h, mode="drop"
    )
    ell_col = jnp.zeros((m_dim, ell_width), jnp.int32).at[rows, dest].set(
        cols, mode="drop"
    )
    # dense tail routing
    rank = jnp.cumsum(is_dense.astype(jnp.int32)) - 1
    dense_map = jnp.where(
        is_dense & (rank < max_dense_rows), rank, -1
    ).astype(jnp.int32)
    tail_dest = jnp.where(dense_map >= 0, dense_map, max_dense_rows)
    dense_tail = jnp.zeros((max_dense_rows, n_dim), h.dtype).at[
        tail_dest
    ].set(h, mode="drop")
    overflow = jnp.any(is_dense & (dense_map < 0))
    return dict(
        ell_val=ell_val, ell_col=ell_col, row_nnz=row_nnz,
        is_dense=is_dense, dense_tail=dense_tail, dense_map=dense_map,
        overflow=overflow, n_dim=n_dim,
    )


def hybrid_matmul(hyb, w):
    """C = hybrid(A) @ W (algorithm 3): ELL gather part + dense-tail part."""
    slot = jax.lax.broadcasted_iota(jnp.int32, hyb["ell_val"].shape, 1)
    valid = (slot < hyb["row_nnz"][:, None]) & (~hyb["is_dense"][:, None])
    coeff = jnp.where(valid, hyb["ell_val"], 0.0)
    w_g = jnp.take(w, hyb["ell_col"], axis=0)      # (M, W, N_out)
    sparse_part = jnp.einsum("mw,mwn->mn", coeff, w_g)
    tail = hyb["dense_tail"] @ w                   # (D, N_out)
    dense_part = jnp.where(
        (hyb["dense_map"] >= 0)[:, None],
        jnp.take(tail, jnp.maximum(hyb["dense_map"], 0), axis=0),
        0.0,
    )
    return sparse_part + dense_part


def hybrid_densify(hyb):
    """Materialize the hybrid matrix back to dense (invariant checks)."""
    m_dim = hyb["row_nnz"].shape[0]
    slot = jax.lax.broadcasted_iota(jnp.int32, hyb["ell_val"].shape, 1)
    valid = (slot < hyb["row_nnz"][:, None]) & (~hyb["is_dense"][:, None])
    rows = jax.lax.broadcasted_iota(jnp.int32, hyb["ell_val"].shape, 0)
    dest_col = jnp.where(valid, hyb["ell_col"], hyb["n_dim"])
    out = jnp.zeros((m_dim, hyb["n_dim"]), hyb["ell_val"].dtype)
    out = out.at[rows, dest_col].set(hyb["ell_val"], mode="drop")
    dense_rows = jnp.where(
        (hyb["dense_map"] >= 0)[:, None],
        jnp.take(hyb["dense_tail"], jnp.maximum(hyb["dense_map"], 0), axis=0),
        0.0,
    )
    return out + dense_rows
