"""Pure-jnp / numpy reference oracles for the sparse kernels.

Everything in this file is deliberately simple (loop-based where that is
the clearest rendering of the paper's pseudocode) so it can serve as the
ground truth for:
  * pytest checks of the Pallas kernels (interpret mode),
  * golden vectors exported for the rust kernel tests (see aot.py
    --goldens), keeping the two implementations of TwELL/hybrid in sync.

Shapes follow the paper's notation: x in R^{M x K}, W_g/W_u in R^{K x N},
W_d in R^{N x K}; TwELL tile width T, compression factor C, slots = T // C.
"""

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Dense feed-forward references (paper eq. 1 / eq. 5)
# ---------------------------------------------------------------------------

def act(z, kind):
    if kind == "relu":
        return jnp.maximum(z, 0.0)
    if kind == "silu":
        return z * (1.0 / (1.0 + jnp.exp(-z)))
    raise ValueError(f"unknown activation {kind!r}")


def gated_ffn(x, wg, wu, wd, activation="relu"):
    """y = (sigma(x Wg) * (x Wu)) Wd — the gated block, paper eq. (1)."""
    hg = act(x @ wg, activation)
    hu = x @ wu
    return (hg * hu) @ wd


def nongated_ffn(x, wu, wd, activation="relu"):
    """y = sigma(x Wu) Wd — the original 2-layer block, paper eq. (5)."""
    return act(x @ wu, activation) @ wd


# ---------------------------------------------------------------------------
# TwELL reference (paper section 3.2, algorithm 1)
# ---------------------------------------------------------------------------

def twell_pack_slow(h, tile_n, comp):
    """Reference TwELL pack via plain python loops (algorithm 1 verbatim).

    h: (M, N) dense post-ReLU activations.
    Returns (h_v, h_i, h_nz) with shapes (M, N // C), (M, N // C), (M, N_T).
    Overflowing non-zeros (more than T/C in one tile) are dropped, matching
    the kernels' drop-and-flag semantics; callers choose C so this never
    happens in practice (paper appendix A.1).
    """
    h = np.asarray(h)
    m_dim, n_dim = h.shape
    assert n_dim % tile_n == 0
    n_tiles = n_dim // tile_n
    slots = tile_n // comp
    h_v = np.zeros((m_dim, n_dim // comp), dtype=h.dtype)
    h_i = np.zeros((m_dim, n_dim // comp), dtype=np.int32)
    h_nz = np.zeros((m_dim, n_tiles), dtype=np.int32)
    for t in range(n_tiles):
        n0 = t * tile_n
        for r in range(m_dim):
            z = 0
            for c in range(tile_n):
                if h[r, n0 + c] > 0:
                    if z < slots:
                        h_v[r, t * slots + z] = h[r, n0 + c]
                        h_i[r, t * slots + z] = n0 + c
                    z += 1
            h_nz[r, t] = min(z, slots)
    return h_v, h_i, h_nz


def twell_unpack(h_v, h_i, h_nz, n_dim, tile_n, comp):
    """Inverse of twell_pack: scatter values back to a dense (M, N)."""
    h_v = np.asarray(h_v)
    h_i = np.asarray(h_i)
    h_nz = np.asarray(h_nz)
    m_dim = h_v.shape[0]
    slots = tile_n // comp
    out = np.zeros((m_dim, n_dim), dtype=h_v.dtype)
    for r in range(m_dim):
        for t in range(h_nz.shape[1]):
            for c in range(h_nz[r, t]):
                j = t * slots + c
                out[r, h_i[r, j]] = h_v[r, j]
    return out


def twell_gate_ref(x, wg, tile_n, comp):
    """Dense gate matmul + ReLU + reference pack (what algorithm 1 fuses)."""
    hg = np.maximum(np.asarray(x) @ np.asarray(wg), 0.0)
    return twell_pack_slow(hg, tile_n, comp)


def fused_ffn_ref(x, wg, wu, wd, tile_n, comp):
    """Reference for the fused inference pipeline (algorithms 1+2, eq. 3).

    Computed the honest sparse way (via the packed format), not as the
    dense formula, so it also exercises the pack/unpack path.
    """
    x = np.asarray(x)
    h_v, h_i, h_nz = twell_gate_ref(x, wg, tile_n, comp)
    wu = np.asarray(wu)
    wd = np.asarray(wd)
    slots = tile_n // comp
    y = np.zeros((x.shape[0], wd.shape[1]), dtype=np.float64)
    for m in range(x.shape[0]):
        for t in range(h_nz.shape[1]):
            for c in range(h_nz[m, t]):
                j = t * slots + c
                n = h_i[m, j]
                u = float(x[m] @ wu[:, n])            # implicit h_u element
                y[m] += float(h_v[m, j]) * u * wd[n]  # scaled W_d row
    return y.astype(x.dtype)


def down_ref(h_v, h_i, h_nz, wd, tile_n, comp):
    """Reference for the non-gated down projection from TwELL (App. A.1)."""
    h_v = np.asarray(h_v)
    h_i = np.asarray(h_i)
    h_nz = np.asarray(h_nz)
    wd = np.asarray(wd)
    slots = tile_n // comp
    m_dim = h_v.shape[0]
    y = np.zeros((m_dim, wd.shape[1]), dtype=np.float64)
    for m in range(m_dim):
        for t in range(h_nz.shape[1]):
            for c in range(h_nz[m, t]):
                j = t * slots + c
                y[m] += float(h_v[m, j]) * wd[h_i[m, j]]
    return y.astype(h_v.dtype)


# ---------------------------------------------------------------------------
# Hybrid format reference (paper section 3.4, algorithm 3)
# ---------------------------------------------------------------------------

def hybrid_partition_slow(h, ell_width, max_dense_rows):
    """Reference hybrid partition: rows with nnz <= ell_width go to the ELL
    component, the rest to the dense backup (up to max_dense_rows, then the
    overflow flag is raised — paper appendix B.2.1)."""
    h = np.asarray(h)
    m_dim, n_dim = h.shape
    ell_val = np.zeros((m_dim, ell_width), dtype=h.dtype)
    ell_col = np.zeros((m_dim, ell_width), dtype=np.int32)
    row_nnz = np.zeros(m_dim, dtype=np.int32)
    is_dense = np.zeros(m_dim, dtype=bool)
    dense_tail = np.zeros((max_dense_rows, n_dim), dtype=h.dtype)
    dense_map = -np.ones(m_dim, dtype=np.int32)
    overflow = False
    next_dense = 0
    for r in range(m_dim):
        cols = np.nonzero(h[r])[0]
        row_nnz[r] = len(cols)
        if len(cols) <= ell_width:
            ell_val[r, : len(cols)] = h[r, cols]
            ell_col[r, : len(cols)] = cols
        else:
            is_dense[r] = True
            if next_dense < max_dense_rows:
                dense_map[r] = next_dense
                dense_tail[next_dense] = h[r]
                next_dense += 1
            else:
                overflow = True
    return dict(
        ell_val=ell_val,
        ell_col=ell_col,
        row_nnz=row_nnz,
        is_dense=is_dense,
        dense_tail=dense_tail,
        dense_map=dense_map,
        n_dense=next_dense,
        overflow=overflow,
        n_dim=n_dim,
    )


def hybrid_to_dense_matmul_ref(hyb, w):
    """C = hybrid(A) @ W, reference for algorithm 3."""
    w = np.asarray(w)
    m_dim = hyb["row_nnz"].shape[0]
    out = np.zeros((m_dim, w.shape[1]), dtype=np.float64)
    for r in range(m_dim):
        if hyb["is_dense"][r]:
            d = hyb["dense_map"][r]
            if d >= 0:
                out[r] = np.asarray(hyb["dense_tail"][d], dtype=np.float64) @ w
        else:
            for k in range(hyb["row_nnz"][r]):
                out[r] += float(hyb["ell_val"][r, k]) * w[hyb["ell_col"][r, k]]
    return out.astype(w.dtype)


def hybrid_densify(hyb):
    """Materialize a hybrid matrix back to dense (for invariant checks)."""
    m_dim = hyb["row_nnz"].shape[0]
    out = np.zeros((m_dim, hyb["n_dim"]), dtype=hyb["ell_val"].dtype)
    for r in range(m_dim):
        if hyb["is_dense"][r]:
            d = hyb["dense_map"][r]
            if d >= 0:
                out[r] = hyb["dense_tail"][d]
        else:
            for k in range(hyb["row_nnz"][r]):
                out[r, hyb["ell_col"][r, k]] = hyb["ell_val"][r, k]
    return out
