"""L2: the paper's Transformer++ language model in pure jnp.

Architecture (paper section 4.1 / appendix B.1, width-scaled): pre-RMSNorm
decoder blocks with RoPE multi-head attention and a gated (or non-gated)
ReLU feed-forward block, tied embeddings, no biases.  The training
objective is cross-entropy plus the paper's L1 activation regularizer
(eq. 2) with a runtime-tunable coefficient, optimized by a handwritten
AdamW (optax is not available in this environment) with gradient clipping.

Everything here is build-time Python: `aot.py` lowers `init`, `train_step`,
`forward`, `score`, `forward_stats` and `reinit_step` once to HLO text and
the rust coordinator drives them through PJRT.  Hyperparameters that the
coordinator sweeps (learning rate, L1 coefficient, step index) are runtime
*inputs* of the lowered functions, so one artifact serves the whole sweep.

The canonical parameter ordering (param_specs) is the contract between
this file and rust/src/runtime/manifest.rs.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import twell as twell_kernels


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig):
    """Canonical (name, shape) list — the flattening contract with rust."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    specs = [("embed", (v, d))]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        specs += [
            (p + "ln_attn", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln_ffn", (d,)),
        ]
        if cfg.gated:
            specs += [(p + "wg", (d, f))]
        specs += [(p + "wu", (d, f)), (p + "wd", (f, d))]
    specs += [("ln_final", (d,))]
    return specs


def _normal(key, shape):
    """Box-Muller standard normal.  jax.random.normal / truncated_normal
    lower to an `erf`/`erf-inv` HLO opcode that the xla_extension 0.5.1
    text parser rejects; uniform + log/cos lower to universally supported
    ops (see DESIGN.md AOT notes)."""
    k1, k2 = jax.random.split(key)
    u1 = jax.random.uniform(k1, shape, jnp.float32, 1e-7, 1.0)
    u2 = jax.random.uniform(k2, shape, jnp.float32)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(2.0 * jnp.pi * u2)


def init_params(cfg: ModelConfig, seed):
    """Initialize parameters (clipped-normal std 0.02, norms at 1)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln_attn", "ln_ffn")) or name == "ln_final":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(
                cfg.init_std * jnp.clip(_normal(sub, shape), -3.0, 3.0)
            )
    return params


def _by_name(cfg: ModelConfig, params):
    return {name: p for (name, _), p in zip(param_specs(cfg), params)}


# ---------------------------------------------------------------------------
# Model forward
# ---------------------------------------------------------------------------

def _rmsnorm(x, w, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(q, theta):
    """Rotary position embedding over the last axis ((B,S,H,Dh))."""
    s, dh = q.shape[1], q.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    q1, q2 = q[..., :half], q[..., half:]
    return jnp.concatenate([q1 * cos - q2 * sin, q1 * sin + q2 * cos], axis=-1)


def _attention(cfg, x, wq, wk, wv, wo):
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, s, h, dh)
    k = (x @ wk).reshape(b, s, h, dh)
    v = (x @ wv).reshape(b, s, h, dh)
    q = _rope(q, cfg.rope_theta)
    k = _rope(k, cfg.rope_theta)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b, s, d)
    return out @ wo


def _activation(cfg, z):
    if cfg.activation == "relu":
        return jnp.maximum(z, 0.0)
    if cfg.activation == "silu":
        return z * jax.nn.sigmoid(z)
    raise ValueError(cfg.activation)


def _ffn(cfg, x, p, prefix, use_pallas=False):
    """Feed-forward block; returns (y, h_gate, h) where h_gate determines
    the sparsity pattern (paper section 2.2 / appendix C.2)."""
    b, s, d = x.shape
    if cfg.gated:
        hg = _activation(cfg, x @ p[prefix + "wg"])
        hu = x @ p[prefix + "wu"]
        h = hg * hu
        if use_pallas:
            xf = x.reshape(b * s, d)
            y = twell_kernels.gated_ffn_twell(
                xf, p[prefix + "wg"], p[prefix + "wu"], p[prefix + "wd"],
                tile_n=cfg.twell_tile_n, comp=1, tile_m=8,
            ).reshape(b, s, d)
        else:
            y = h @ p[prefix + "wd"]
        return y, hg, h
    hg = _activation(cfg, x @ p[prefix + "wu"])
    if use_pallas:
        xf = x.reshape(b * s, d)
        y = twell_kernels.nongated_ffn_twell(
            xf, p[prefix + "wu"], p[prefix + "wd"],
            tile_n=cfg.twell_tile_n, comp=1, tile_m=8,
        ).reshape(b, s, d)
    else:
        y = hg @ p[prefix + "wd"]
    return y, hg, hg


def forward(cfg: ModelConfig, params, tokens, use_pallas=False):
    """Full forward pass.

    Returns (logits f32[B,S,V], gates: list of f32[B,S,F] gate activations
    per layer, hs: list of f32[B,S,F] combined hidden h per layer).
    """
    p = _by_name(cfg, params)
    x = jnp.take(p["embed"], tokens, axis=0)
    gates, hs = [], []
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        a = _attention(
            cfg, _rmsnorm(x, p[pre + "ln_attn"], cfg.rmsnorm_eps),
            p[pre + "wq"], p[pre + "wk"], p[pre + "wv"], p[pre + "wo"],
        )
        x = x + a
        y, hg, h = _ffn(
            cfg, _rmsnorm(x, p[pre + "ln_ffn"], cfg.rmsnorm_eps), p, pre,
            use_pallas=use_pallas,
        )
        x = x + y
        gates.append(hg)
        hs.append(h)
    x = _rmsnorm(x, p["ln_final"], cfg.rmsnorm_eps)
    logits = x @ p["embed"].T  # tied embeddings
    return logits, gates, hs


# ---------------------------------------------------------------------------
# Loss + sparsity statistics
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, tokens, l1_coeff):
    """CE + L1 activation regularizer (paper eq. 2) + sparsity stats."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits, gates, hs = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))
    # eq. (2): mean |h| over layers, tokens and hidden units
    l1 = jnp.mean(jnp.stack([jnp.mean(jnp.abs(h)) for h in hs]))
    loss = ce + l1_coeff * l1
    nnz = jnp.stack([jnp.mean(jnp.sum(g > 0, axis=-1).astype(jnp.float32))
                     for g in gates])                       # [L] avg per token
    active = jnp.stack([jnp.sum((g > 0).reshape(-1, g.shape[-1]), axis=0)
                        .astype(jnp.float32) for g in gates])  # [L, F]
    return loss, (ce, l1, nnz, active)


# ---------------------------------------------------------------------------
# Handwritten AdamW + gradient clipping (appendix B.1 hyperparameters)
# ---------------------------------------------------------------------------

B1, B2, EPS = 0.9, 0.95, 1e-8
MAX_GRAD_NORM = 1.0


def _decay_mask(cfg: ModelConfig):
    """Weight decay on matmul weights + embeddings, not on norms."""
    return [0.0 if name.endswith(("ln_attn", "ln_ffn")) or name == "ln_final"
            else 1.0 for name, _ in param_specs(cfg)]


def adamw_update(cfg, params, grads, ms, vs, lr, wd, step):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, MAX_GRAD_NORM / (gnorm + 1e-12))
    t = step + 1.0
    bc1 = 1.0 - B1 ** t
    bc2 = 1.0 - B2 ** t
    new_p, new_m, new_v = [], [], []
    for p, g, m, v, dk in zip(params, grads, ms, vs, _decay_mask(cfg)):
        g = g * scale
        m = B1 * m + (1.0 - B1) * g
        v = B2 * v + (1.0 - B2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + EPS) + wd * dk * p
        new_p.append(p - lr * upd)
        new_m.append(m)
        new_v.append(v)
    return new_p, new_m, new_v, gnorm


def train_step(cfg: ModelConfig, params, ms, vs, tokens, lr, l1_coeff,
               step, weight_decay=0.1):
    """One optimizer step.  All sweep-able knobs are runtime inputs."""
    (loss, (ce, l1, nnz, active)), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, l1_coeff), has_aux=True
    )(params)
    new_p, new_m, new_v, gnorm = adamw_update(
        cfg, params, grads, ms, vs, lr, weight_decay, step
    )
    return new_p, new_m, new_v, loss, ce, l1, nnz, active, gnorm


# ---------------------------------------------------------------------------
# Evaluation / analysis entry points
# ---------------------------------------------------------------------------

def score(cfg: ModelConfig, params, tokens):
    """Per-position target log-prob (cloze scoring) + per-layer mean nnz."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits, gates, _ = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    nnz = jnp.stack([jnp.mean(jnp.sum(g > 0, axis=-1).astype(jnp.float32))
                     for g in gates])
    return tgt, nnz


def forward_stats(cfg: ModelConfig, params, tokens):
    """Per-layer per-position gate nnz (figures 6/7/10/11 raw data)."""
    _, gates, _ = forward(cfg, params, tokens)
    return jnp.stack([jnp.sum(g > 0, axis=-1).astype(jnp.float32)
                      for g in gates])   # [L, B, S]


def reinit_step(cfg: ModelConfig, params, active, seed, lam):
    """Targeted dead-neuron reinitialization (paper eq. 6, appendix C.3).

    For gate-projection columns whose neuron was inactive over the whole
    step (active[l, j] == 0), interpolate the column toward fresh noise:
    W_g[:, j] <- (1 - lam) W_g[:, j] + lam N(0, sigma^2).
    """
    key = jax.random.PRNGKey(0)
    key = jax.random.fold_in(key, seed)
    names = [name for name, _ in param_specs(cfg)]
    out = list(params)
    gate_name = "wg" if cfg.gated else "wu"
    for l in range(cfg.n_layers):
        target = f"layer{l}.{gate_name}"
        idx = names.index(target)
        w = out[idx]
        key, sub = jax.random.split(key)
        noise = cfg.init_std * _normal(sub, w.shape)
        dead = (active[l] == 0.0)[None, :]  # column-wise mask
        out[idx] = jnp.where(dead, (1.0 - lam) * w + lam * noise, w)
    return out


def ffn_twell_demo(cfg: ModelConfig, x, wg, wu, wd):
    """Single gated FFN block through the Pallas TwELL pipeline — the
    artifact that proves L1 kernels compose through AOT into rust."""
    return twell_kernels.gated_ffn_twell(
        x, wg, wu, wd, tile_n=cfg.twell_tile_n, comp=1, tile_m=8
    )
