"""Model/training preset definitions shared between the python compile path
and the rust coordinator (via the AOT manifest).

The presets are width-scaled stand-ins for the paper's 0.5B/1B/1.5B/2B
models (hidden 2048, ffn 5632, layers 8/18/28/38): we keep the exact shape
ratios (d_ff = 8/3 * d_model gated, 4 * d_model non-gated; head_dim 64 ->
scaled to 32) and scale width by 1/16.  See DESIGN.md section 5.
"""

from dataclasses import dataclass, asdict, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 352            # gated: ~8/3 * d_model, multiple of 16
    gated: bool = True
    activation: str = "relu"   # "relu" | "silu"
    rope_theta: float = 10_000.0
    tied_embeddings: bool = True
    rmsnorm_eps: float = 1e-5
    init_std: float = 0.02
    # static execution shapes baked into the AOT artifacts
    train_batch: int = 16
    seq_len: int = 128
    score_batch: int = 32
    # TwELL / hybrid kernel parameters (paper section 3; appendix B.2.1)
    twell_tile_n: int = 32
    twell_comp: int = 4        # compression factor C; slots per tile = T/C
    ell_width: int = 128       # hybrid ELL max nnz per row
    dense_backup_frac: float = 0.125  # dense tail rows = frac * M

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)


_BASE = ModelConfig(name="base")

# Scale family (stand-ins for the paper's 0.5B/1B/1.5B/2B chinchilla runs).
PRESETS = {
    "xs": replace(_BASE, name="xs", n_layers=2),
    "s": replace(_BASE, name="s", n_layers=4),
    "m": replace(_BASE, name="m", n_layers=6),
    "l": replace(_BASE, name="l", n_layers=8),
    # appendix C variants (on the `m` scale, like the paper's 1.5B studies)
    "m-silu": replace(_BASE, name="m-silu", n_layers=6, activation="silu"),
    "m-nongated": replace(
        _BASE, name="m-nongated", n_layers=6, gated=False, d_ff=512
    ),
    # tiny preset for tests and the quickstart example
    "tiny": replace(
        _BASE,
        name="tiny",
        vocab_size=320,
        d_model=64,
        n_layers=2,
        n_heads=2,
        d_ff=176,
        train_batch=4,
        seq_len=64,
        score_batch=8,
        ell_width=64,
        twell_tile_n=16,
    ),
}

# The paper's L1-coefficient grid (section 4.2).  Our scaled models sit in a
# different loss landscape, so the coordinator rescales this grid by
# `l1_scale` recorded in EXPERIMENTS.md; the *relative* spacing is kept.
L1_GRID = [0.0, 5e-6, 1e-5, 1.5e-5, 2e-5, 3e-5, 5e-5, 1e-4]
