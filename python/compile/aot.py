"""AOT compile path: lower the L2 model to HLO *text* artifacts + manifest.

Run once by `make artifacts`; python never appears on the request path.
Interchange format is HLO text, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Per preset this emits:
    artifacts/<preset>/init.hlo.txt          seed               -> params
    artifacts/<preset>/train_step.hlo.txt    params,opt,tokens, -> params',
                                             lr,l1,step            opt',stats
    artifacts/<preset>/train_step8.hlo.txt   8 microbatches per call
                                             (lax.scan — amortizes the PJRT
                                             host round-trip; §Perf L2)
    artifacts/<preset>/forward.hlo.txt       params,tokens      -> logits
    artifacts/<preset>/score.hlo.txt         params,tokens      -> logprob,nnz
    artifacts/<preset>/forward_stats.hlo.txt params,tokens      -> nnz[L,B,S]
    artifacts/<preset>/reinit.hlo.txt        params,active,seed,lam -> params
    artifacts/<preset>/manifest.json         io contract for rust
plus (tiny preset) ffn_twell.hlo.txt — the Pallas TwELL FFN lowered through
interpret mode, proving the L1 kernel composes through AOT into rust.

`--goldens` additionally dumps reference vectors for the rust sparse-kernel
tests so the two TwELL/hybrid implementations stay in lockstep.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import PRESETS, L1_GRID
from .kernels import ref

SCAN_K = 8  # microbatches fused per train_step8 call


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32",
            "bool": "pred"}[np.dtype(dt).name]


def _io_spec(fn, example_args):
    """Describe the flat input/output avals of `fn` for the manifest."""
    out = jax.eval_shape(fn, *example_args)
    flat_out, _ = jax.tree_util.tree_flatten(out)
    ins = [
        {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)}
        for a in jax.tree_util.tree_leaves(example_args)
    ]
    outs = [{"shape": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in flat_out]
    return ins, outs


def _lower(fn, example_args, path):
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*example_args))
    with open(path, "w") as f:
        f.write(text)
    return _io_spec(fn, example_args)


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_step_k(cfg, params, ms, vs, tokens_k, lr_k, l1_coeff, step0):
    """SCAN_K optimizer steps per PJRT call (host-round-trip amortization)."""
    n = len(params)

    def body(carry, inp):
        params, ms, vs, i = carry
        tokens, lr = inp
        p, m, v, loss, ce, l1, nnz, active, gnorm = M.train_step(
            cfg, list(params), list(ms), list(vs), tokens, lr, l1_coeff,
            step0 + i,
        )
        return (tuple(p), tuple(m), tuple(v), i + 1.0), (
            loss, ce, nnz, active, gnorm,
        )

    (p, m, v, _), (loss, ce, nnz, active, gnorm) = jax.lax.scan(
        body, (tuple(params), tuple(ms), tuple(vs), 0.0), (tokens_k, lr_k)
    )
    return (list(p), list(m), list(v), loss, ce, nnz,
            jnp.sum(active, axis=0), gnorm)


def build_preset(name: str, outdir: str) -> dict:
    cfg = PRESETS[name]
    d = os.path.join(outdir, name)
    os.makedirs(d, exist_ok=True)
    specs = M.param_specs(cfg)
    pspecs = [_spec(s) for _, s in specs]
    b, s = cfg.train_batch, cfg.seq_len
    tok_train = _spec((b, s + 1), jnp.int32)
    tok_fwd = _spec((cfg.score_batch, s), jnp.int32)
    tok_score = _spec((cfg.score_batch, s + 1), jnp.int32)
    scalar_f = _spec((), jnp.float32)
    scalar_i = _spec((), jnp.int32)
    arts = {}

    def emit(key, fn, args):
        path = os.path.join(d, f"{key}.hlo.txt")
        ins, outs = _lower(fn, args, path)
        arts[key] = {"file": f"{key}.hlo.txt", "inputs": ins, "outputs": outs}
        print(f"  [{name}] {key}: {len(ins)} in / {len(outs)} out")

    emit("init", lambda seed: M.init_params(cfg, seed), (scalar_i,))
    n = len(pspecs)
    emit(
        "train_step",
        lambda *a: M.train_step(
            cfg, list(a[:n]), list(a[n:2 * n]), list(a[2 * n:3 * n]),
            a[3 * n], a[3 * n + 1], a[3 * n + 2], a[3 * n + 3],
        ),
        (*pspecs, *pspecs, *pspecs, tok_train, scalar_f, scalar_f, scalar_f),
    )
    emit(
        "train_step8",
        lambda *a: train_step_k(
            cfg, list(a[:n]), list(a[n:2 * n]), list(a[2 * n:3 * n]),
            a[3 * n], a[3 * n + 1], a[3 * n + 2], a[3 * n + 3],
        ),
        (*pspecs, *pspecs, *pspecs,
         _spec((SCAN_K, b, s + 1), jnp.int32), _spec((SCAN_K,)),
         scalar_f, scalar_f),
    )
    emit(
        "forward",
        lambda *a: M.forward(cfg, list(a[:n]), a[n])[0],
        (*pspecs, tok_fwd),
    )
    emit(
        "score",
        lambda *a: M.score(cfg, list(a[:n]), a[n]),
        (*pspecs, tok_score),
    )
    emit(
        "forward_stats",
        lambda *a: M.forward_stats(cfg, list(a[:n]), a[n]),
        (*pspecs, tok_fwd),
    )
    emit(
        "reinit",
        lambda *a: M.reinit_step(cfg, list(a[:n]), a[n], a[n + 1], a[n + 2]),
        (*pspecs, _spec((cfg.n_layers, cfg.d_ff)), scalar_i, scalar_f),
    )
    if name == "tiny":
        # Pallas TwELL FFN through AOT (integration proof; small shapes —
        # interpret-mode pallas lowers to sizeable HLO)
        emit(
            "ffn_twell",
            lambda x, wg, wu, wd: M.ffn_twell_demo(cfg, x, wg, wu, wd),
            (_spec((32, cfg.d_model)), _spec((cfg.d_model, cfg.d_ff)),
             _spec((cfg.d_model, cfg.d_ff)), _spec((cfg.d_ff, cfg.d_model))),
        )

    manifest = {
        "preset": name,
        "config": cfg.to_dict(),
        "scan_k": SCAN_K,
        "l1_grid": L1_GRID,
        "params": [
            {"name": nm, "shape": list(sh)} for nm, sh in specs
        ],
        "artifacts": arts,
    }
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


# ---------------------------------------------------------------------------
# Golden vectors for the rust sparse kernels
# ---------------------------------------------------------------------------

def dump_goldens(outdir: str):
    """Small reference vectors keeping rust's TwELL/hybrid in lockstep with
    ref.py.  Flat JSON (lists) — parsed by rust/src/util/json.rs."""
    rng = np.random.default_rng(1234)
    m_dim, k_dim, n_dim = 24, 16, 64
    tile_n, comp = 32, 2
    x = rng.normal(size=(m_dim, k_dim)).astype(np.float32)
    wg = (rng.normal(size=(k_dim, n_dim)) * 0.2).astype(np.float32)
    wu = (rng.normal(size=(k_dim, n_dim)) * 0.2).astype(np.float32)
    wd = (rng.normal(size=(n_dim, k_dim)) * 0.2).astype(np.float32)
    # bias the gate toward sparsity so packs don't overflow
    hg = np.maximum(x @ wg - 0.8, 0.0)
    h_v, h_i, h_nz = ref.twell_pack_slow(hg, tile_n, comp)
    y_fused = np.zeros((m_dim, k_dim), np.float64)
    slots = tile_n // comp
    for mm in range(m_dim):
        for t in range(h_nz.shape[1]):
            for c in range(h_nz[mm, t]):
                j = t * slots + c
                nn = h_i[mm, j]
                u = float(x[mm] @ wu[:, nn])
                y_fused[mm] += float(h_v[mm, j]) * u * wd[nn]
    hyb = ref.hybrid_partition_slow(hg, 8, 8)
    w2 = (rng.normal(size=(n_dim, k_dim)) * 0.2).astype(np.float32)
    y_hyb = ref.hybrid_to_dense_matmul_ref(hyb, w2)
    golden = {
        "m": m_dim, "k": k_dim, "n": n_dim, "tile_n": tile_n, "comp": comp,
        "x": x.flatten().tolist(),
        "wg": wg.flatten().tolist(),
        "wu": wu.flatten().tolist(),
        "wd": wd.flatten().tolist(),
        "gate_bias": 0.8,
        "h_v": h_v.flatten().tolist(),
        "h_i": h_i.flatten().astype(int).tolist(),
        "h_nz": h_nz.flatten().astype(int).tolist(),
        "y_fused": y_fused.astype(np.float32).flatten().tolist(),
        "ell_width": 8,
        "max_dense_rows": 8,
        "ell_val": hyb["ell_val"].flatten().tolist(),
        "ell_col": hyb["ell_col"].flatten().astype(int).tolist(),
        "row_nnz": hyb["row_nnz"].astype(int).tolist(),
        "is_dense": [int(v) for v in hyb["is_dense"]],
        "w2": w2.flatten().tolist(),
        "y_hybrid": y_hyb.astype(np.float32).flatten().tolist(),
    }
    path = os.path.join(outdir, "goldens.json")
    with open(path, "w") as f:
        json.dump(golden, f)
    print(f"  goldens -> {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,xs,s,m,l,m-silu,m-nongated")
    ap.add_argument("--goldens", action="store_true", default=True)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for preset in args.presets.split(","):
        preset = preset.strip()
        if not preset:
            continue
        print(f"lowering preset {preset} ...")
        build_preset(preset, args.out)
    if args.goldens:
        dump_goldens(args.out)
    print("AOT artifacts complete.")


if __name__ == "__main__":
    main()
