"""L2 model tests: shapes, gradients, optimizer, sparsity statistics,
dead-neuron reinit, and a short loss-goes-down training run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import PRESETS, ModelConfig

CFG = PRESETS["tiny"]


def _params(cfg=CFG, seed=0):
    return M.init_params(cfg, seed)


def _tokens(cfg=CFG, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=(b, s)),
                       dtype=jnp.int32)


def test_param_specs_cover_init():
    params = _params()
    specs = M.param_specs(CFG)
    assert len(params) == len(specs)
    for p, (name, shape) in zip(params, specs):
        assert p.shape == shape, name


def test_forward_shapes():
    params = _params()
    toks = _tokens()
    logits, gates, hs = M.forward(CFG, params, toks)
    assert logits.shape == (2, 16, CFG.vocab_size)
    assert len(gates) == CFG.n_layers
    assert gates[0].shape == (2, 16, CFG.d_ff)


def test_loss_finite_and_l1_increases_loss():
    params = _params()
    toks = _tokens(s=17)
    loss0, (ce0, l1_0, nnz, active) = M.loss_fn(CFG, params, toks, 0.0)
    loss1, _ = M.loss_fn(CFG, params, toks, 1.0)
    assert np.isfinite(float(loss0))
    assert float(loss1) > float(loss0)
    assert float(loss0) == pytest.approx(float(ce0))
    assert nnz.shape == (CFG.n_layers,)
    assert active.shape == (CFG.n_layers, CFG.d_ff)


def test_initial_ce_close_to_uniform():
    params = _params()
    toks = _tokens(s=17)
    _, (ce, _, _, _) = M.loss_fn(CFG, params, toks, 0.0)
    assert abs(float(ce) - np.log(CFG.vocab_size)) < 0.5


def test_nnz_consistent_with_activations():
    params = _params()
    toks = _tokens()
    _, gates, _ = M.forward(CFG, params, toks)
    nnz_direct = float(jnp.mean(jnp.sum(gates[0] > 0, axis=-1)))
    stats = M.forward_stats(CFG, params, toks)
    assert stats.shape == (CFG.n_layers, 2, 16)
    assert float(jnp.mean(stats[0])) == pytest.approx(nnz_direct)


def test_adamw_matches_manual_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = CFG
    params = _params()
    grads = [jnp.ones_like(p) * 0.01 for p in params]
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    lr, wd, step = 1e-3, 0.1, 0.0
    new_p, new_m, new_v, gnorm = M.adamw_update(cfg, params, grads, ms, vs,
                                                lr, wd, step)
    g = np.concatenate([np.asarray(x).ravel() for x in grads])
    expect_norm = np.sqrt((g * g).sum())
    assert float(gnorm) == pytest.approx(expect_norm, rel=1e-5)
    scale = min(1.0, M.MAX_GRAD_NORM / (expect_norm + 1e-12))
    i = 0  # embed (decayed)
    g0 = np.asarray(grads[i]) * scale
    m0 = (1 - M.B1) * g0
    v0 = (1 - M.B2) * g0 * g0
    upd = (m0 / (1 - M.B1)) / (np.sqrt(v0 / (1 - M.B2)) + M.EPS) \
        + wd * np.asarray(params[i])
    np.testing.assert_allclose(np.asarray(new_p[i]),
                               np.asarray(params[i]) - lr * upd,
                               rtol=1e-4, atol=1e-9)


def test_norm_weights_not_decayed():
    mask = M._decay_mask(CFG)
    names = [n for n, _ in M.param_specs(CFG)]
    for n, m in zip(names, mask):
        if "ln" in n:
            assert m == 0.0, n
        else:
            assert m == 1.0, n


def test_train_loop_loss_decreases():
    """A few dozen steps on a repetitive corpus: loss must drop clearly."""
    cfg = PRESETS["tiny"]
    params = _params(cfg, seed=1)
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(0)
    base = rng.integers(0, 50, size=33)

    step_fn = jax.jit(lambda p, m, v, t, s: M.train_step(
        cfg, p, m, v, t, 3e-3, 0.0, s))
    losses = []
    for i in range(40):
        batch = np.stack([np.roll(base, k % 7) for k in range(4)])
        toks = jnp.asarray(batch, dtype=jnp.int32)
        params, ms, vs, loss, *_ = step_fn(params, ms, vs, toks, float(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_l1_regularization_induces_sparsity():
    """Strong L1 for a few steps must reduce the mean nnz (paper fig. 9:
    sparsity settles early in training)."""
    cfg = PRESETS["tiny"]
    toks = _tokens(cfg, b=4, s=33, seed=3)

    def run(l1):
        params = _params(cfg, seed=2)
        ms = [jnp.zeros_like(p) for p in params]
        vs = [jnp.zeros_like(p) for p in params]
        step_fn = jax.jit(lambda p, m, v, t, s: M.train_step(
            cfg, p, m, v, t, 3e-3, l1, s))
        nnz = None
        for i in range(80):
            params, ms, vs, loss, ce, l1v, nnz, active, gn = step_fn(
                params, ms, vs, toks, float(i))
        return float(jnp.mean(nnz))

    # NOTE: our width-scaled models live at a different loss scale than the
    # paper's billion-parameter runs, so the *effective* L1 grid is shifted
    # (recorded as `l1_scale` in EXPERIMENTS.md); 1.0 here plays the role
    # of the paper's ~3e-5 "visible sparsification" point.
    assert run(1.0) < run(0.0) * 0.7


def test_reinit_only_touches_dead_columns():
    params = _params()
    active = jnp.ones((CFG.n_layers, CFG.d_ff))
    active = active.at[0, 5].set(0.0)  # one dead neuron
    out = M.reinit_step(CFG, params, active, 7, 0.1)
    names = [n for n, _ in M.param_specs(CFG)]
    iwg = names.index("layer0.wg")
    before = np.asarray(params[iwg])
    after = np.asarray(out[iwg])
    changed = np.any(before != after, axis=0)
    assert changed[5] and changed.sum() == 1
    # all other params untouched
    for i, (b, a) in enumerate(zip(params, out)):
        if i != iwg:
            np.testing.assert_array_equal(np.asarray(b), np.asarray(a))


def test_silu_variant_never_sparse():
    cfg = PRESETS["tiny"]
    cfg_silu = ModelConfig(**{**cfg.to_dict(), "name": "t-silu",
                              "activation": "silu"})
    params = M.init_params(cfg_silu, 0)
    _, gates, _ = M.forward(cfg_silu, params, _tokens(cfg_silu))
    # silu(z) = 0 only at z == 0 exactly: nnz ~ full width
    assert float(jnp.mean(jnp.sum(gates[0] > 0, axis=-1))) > cfg.d_ff * 0.4


def test_nongated_variant_shapes():
    cfg = PRESETS["tiny"]
    cfg_ng = ModelConfig(**{**cfg.to_dict(), "name": "t-ng", "gated": False,
                            "d_ff": 256})
    params = M.init_params(cfg_ng, 0)
    assert all("wg" not in n for n, _ in M.param_specs(cfg_ng))
    logits, gates, hs = M.forward(cfg_ng, params, _tokens(cfg_ng))
    assert logits.shape[-1] == cfg_ng.vocab_size
    assert gates[0].shape[-1] == 256


def test_pallas_ffn_model_matches_dense_model():
    """The whole model with use_pallas=True equals the jnp path (comp=1)."""
    cfg = PRESETS["tiny"]
    params = _params(cfg)
    toks = _tokens(cfg, b=2, s=64)  # b*s must be a multiple of tile_m=8
    logits_d, _, _ = M.forward(cfg, params, toks, use_pallas=False)
    logits_p, _, _ = M.forward(cfg, params, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=2e-3, atol=2e-4)
