"""Pallas TwELL kernels vs the pure reference (the core L1 signal).

Includes hypothesis sweeps over shapes / tile sizes / compression factors /
sparsity levels, per the paper's claim that TwELL is correct for any
sparsity below the compression bound and drop-consistent above it.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, twell


def _mats(rng, m, k, n, scale=0.2):
    x = rng.normal(size=(m, k)).astype(np.float32)
    wg = (rng.normal(size=(k, n)) * scale).astype(np.float32)
    wu = (rng.normal(size=(k, n)) * scale).astype(np.float32)
    wd = (rng.normal(size=(n, k)) * scale).astype(np.float32)
    return x, wg, wu, wd


@pytest.mark.parametrize("comp", [1, 2, 4])
@pytest.mark.parametrize("tile_n", [16, 32])
def test_gate_pack_matches_reference(tile_n, comp):
    rng = np.random.default_rng(0)
    x, wg, _, _ = _mats(rng, 16, 24, 64)
    hv, hi, hnz = twell.twell_gate_matmul(x, wg, tile_n=tile_n, comp=comp,
                                          tile_m=8)
    rv, ri, rnz = ref.twell_gate_ref(x, wg, tile_n, comp)
    np.testing.assert_allclose(np.asarray(hv), rv, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(hi), ri)
    np.testing.assert_array_equal(np.asarray(hnz), rnz)


def test_pack_unpack_roundtrip_when_no_overflow():
    rng = np.random.default_rng(1)
    x, wg, _, _ = _mats(rng, 16, 16, 96)
    hv, hi, hnz = twell.twell_gate_matmul(x, wg, tile_n=32, comp=1, tile_m=8)
    hg = np.maximum(x @ wg, 0.0)
    back = ref.twell_unpack(hv, hi, hnz, 96, 32, 1)
    np.testing.assert_allclose(back, hg, rtol=1e-5, atol=1e-6)


def test_fused_ffn_matches_sparse_reference():
    rng = np.random.default_rng(2)
    x, wg, wu, wd = _mats(rng, 16, 24, 64)
    hv, hi, hnz = twell.twell_gate_matmul(x, wg, tile_n=32, comp=2, tile_m=8)
    y = twell.twell_fused_ffn(x, hv, hi, hnz, wu, wd, tile_n=32, comp=2,
                              tile_m=8)
    yref = ref.fused_ffn_ref(x, wg, wu, wd, 32, 2)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=1e-3, atol=1e-4)


def test_full_pipeline_matches_dense_without_overflow():
    rng = np.random.default_rng(3)
    x, wg, wu, wd = _mats(rng, 24, 32, 64)
    y = twell.gated_ffn_twell(x, wg, wu, wd, tile_n=32, comp=1, tile_m=8)
    ydense = np.asarray(ref.gated_ffn(x, wg, wu, wd))
    np.testing.assert_allclose(np.asarray(y), ydense, rtol=1e-3, atol=1e-4)


def test_down_matmul_nongated():
    rng = np.random.default_rng(4)
    x, wu, _, wd = _mats(rng, 16, 24, 64)
    hv, hi, hnz = twell.twell_gate_matmul(x, wu, tile_n=32, comp=2, tile_m=8)
    y = twell.twell_down_matmul(hv, hi, hnz, wd, tile_n=32, comp=2, tile_m=8)
    yref = ref.down_ref(hv, hi, hnz, wd, 32, 2)
    np.testing.assert_allclose(np.asarray(y), yref, rtol=1e-3, atol=1e-4)


def test_nongated_pipeline_matches_dense():
    rng = np.random.default_rng(5)
    x, wu, _, wd = _mats(rng, 16, 24, 64)
    y = twell.nongated_ffn_twell(x, wu, wd, tile_n=32, comp=1, tile_m=8)
    ydense = np.asarray(ref.nongated_ffn(x, wu, wd))
    np.testing.assert_allclose(np.asarray(y), ydense, rtol=1e-3, atol=1e-4)


def test_overflow_drops_are_counted_not_corrupted():
    """Above the compression bound the kernel must drop the overflow but
    keep the first T/C entries and report the clipped count — never write
    out of bounds (paper app. A.1's flag-and-retry contract)."""
    rng = np.random.default_rng(6)
    # dense positive activations: every tile overflows for comp >= 2
    x = np.abs(rng.normal(size=(8, 8))).astype(np.float32) + 0.1
    wg = np.abs(rng.normal(size=(8, 32))).astype(np.float32)
    hv, hi, hnz = twell.twell_gate_matmul(x, wg, tile_n=16, comp=4, tile_m=8)
    slots = 16 // 4
    assert np.asarray(hnz).max() <= slots
    rv, ri, rnz = ref.twell_pack_slow(np.maximum(x @ wg, 0), 16, 4)
    np.testing.assert_allclose(np.asarray(hv), rv, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(hnz), rnz)


@settings(max_examples=25, deadline=None)
@given(
    m_tiles=st.integers(1, 3),
    k=st.integers(4, 48),
    n_tiles=st.integers(1, 3),
    tile_n=st.sampled_from([16, 32]),
    comp=st.sampled_from([1, 2, 4]),
    bias=st.floats(0.0, 1.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_pack_matches_reference(m_tiles, k, n_tiles, tile_n,
                                           comp, bias, seed):
    """Property: for any shape/tile/compression/sparsity, the Pallas pack
    equals the loop reference (incl. drop semantics on overflow)."""
    rng = np.random.default_rng(seed)
    m, n = 8 * m_tiles, tile_n * n_tiles
    x = rng.normal(size=(m, k)).astype(np.float32)
    # `bias` shifts the gate pre-activation to sweep sparsity 0..~100%
    wg = (rng.normal(size=(k, n)) * 0.3).astype(np.float32)
    x = x - bias
    hv, hi, hnz = twell.twell_gate_matmul(x, wg, tile_n=tile_n, comp=comp,
                                          tile_m=8)
    rv, ri, rnz = ref.twell_gate_ref(x, wg, tile_n, comp)
    np.testing.assert_allclose(np.asarray(hv), rv, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(hi), ri)
    np.testing.assert_array_equal(np.asarray(hnz), rnz)


@settings(max_examples=15, deadline=None)
@given(
    m_tiles=st.integers(1, 2),
    k=st.integers(8, 32),
    n_tiles=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_fused_ffn_matches_dense(m_tiles, k, n_tiles, seed):
    """Property: with comp=1 (no overflow possible) the two-kernel sparse
    pipeline is exactly the dense gated FFN."""
    rng = np.random.default_rng(seed)
    m, n = 8 * m_tiles, 32 * n_tiles
    x, wg, wu, wd = _mats(rng, m, k, n)
    y = twell.gated_ffn_twell(x, wg, wu, wd, tile_n=32, comp=1, tile_m=8)
    ydense = np.asarray(ref.gated_ffn(x, wg, wu, wd))
    np.testing.assert_allclose(np.asarray(y), ydense, rtol=2e-3, atol=2e-4)
