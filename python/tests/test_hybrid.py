"""Hybrid ELL+dense training format: Pallas compaction kernel + jnp ops vs
the loop reference (paper section 3.4, listing 4)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import hybrid, ref, twell


def _sparse_h(rng, m, n, density):
    h = np.maximum(rng.normal(size=(m, n)), 0.0).astype(np.float32)
    mask = rng.random((m, n)) < density
    return h * mask


def test_twell_to_ell_matches_reference():
    rng = np.random.default_rng(0)
    h = _sparse_h(rng, 16, 64, 0.15)
    hv, hi, hnz = ref.twell_pack_slow(h, 32, 1)  # comp=1: lossless
    ev, ec, rn, l0, l1 = hybrid.twell_to_ell(
        hv.astype(np.float32), hi, hnz, tile_n=32, comp=1, ell_width=32,
        tile_m=8,
    )
    hyb = ref.hybrid_partition_slow(h, 32, 4)
    fits = hyb["row_nnz"] <= 32
    np.testing.assert_allclose(np.asarray(ev)[fits], hyb["ell_val"][fits],
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(ec)[fits], hyb["ell_col"][fits])
    np.testing.assert_array_equal(np.asarray(rn)[:, 0], hyb["row_nnz"])


def test_twell_to_ell_stats():
    """L0/L1 statistics from the compaction kernel (listing 4 lines 43-51)."""
    rng = np.random.default_rng(1)
    h = _sparse_h(rng, 8, 32, 0.3)
    hv, hi, hnz = ref.twell_pack_slow(h, 16, 1)
    _, _, rn, l0, l1 = hybrid.twell_to_ell(
        hv.astype(np.float32), hi, hnz, tile_n=16, comp=1, ell_width=32,
        tile_m=8,
    )
    np.testing.assert_allclose(np.asarray(l0)[:, 0],
                               (h > 0).sum(axis=1).astype(np.float32))
    np.testing.assert_allclose(np.asarray(l1)[:, 0], h.sum(axis=1),
                               rtol=1e-5)


def test_partition_matches_reference():
    rng = np.random.default_rng(2)
    h = _sparse_h(rng, 24, 48, 0.2)
    # one deliberately dense row to exercise the dense tail
    h[3] = np.abs(rng.normal(size=48)).astype(np.float32) + 0.1
    hyb_j = hybrid.hybrid_partition(h, ell_width=8, max_dense_rows=4)
    hyb_r = ref.hybrid_partition_slow(h, 8, 4)
    np.testing.assert_array_equal(np.asarray(hyb_j["row_nnz"]),
                                  hyb_r["row_nnz"])
    np.testing.assert_array_equal(np.asarray(hyb_j["is_dense"]),
                                  hyb_r["is_dense"])
    fits = ~hyb_r["is_dense"]
    np.testing.assert_allclose(np.asarray(hyb_j["ell_val"])[fits],
                               hyb_r["ell_val"][fits], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(hybrid.hybrid_densify(hyb_j)),
                               ref.hybrid_densify(hyb_r), rtol=1e-6)


def test_hybrid_matmul_matches_dense():
    rng = np.random.default_rng(3)
    h = _sparse_h(rng, 16, 32, 0.25)
    h[0] = np.abs(rng.normal(size=32)).astype(np.float32) + 0.1  # dense row
    w = (rng.normal(size=(32, 12)) * 0.3).astype(np.float32)
    hyb = hybrid.hybrid_partition(h, ell_width=8, max_dense_rows=4)
    y = hybrid.hybrid_matmul(hyb, w)
    np.testing.assert_allclose(np.asarray(y), h @ w, rtol=1e-4, atol=1e-5)


def test_densify_roundtrip():
    rng = np.random.default_rng(4)
    h = _sparse_h(rng, 16, 32, 0.2)
    hyb = hybrid.hybrid_partition(h, ell_width=16, max_dense_rows=4)
    np.testing.assert_allclose(np.asarray(hybrid.hybrid_densify(hyb)), h,
                               rtol=1e-6)


def test_overflow_flag():
    """More dense rows than the tail holds -> overflow flag, no crash
    (appendix B.2.1 flag-and-retry contract)."""
    rng = np.random.default_rng(5)
    h = np.abs(rng.normal(size=(8, 32))).astype(np.float32) + 0.1
    hyb = hybrid.hybrid_partition(h, ell_width=4, max_dense_rows=2)
    assert bool(hyb["overflow"])
    assert int(np.asarray(hyb["dense_map"]).max()) <= 1


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(2, 24),
    n=st.integers(8, 64),
    density=st.floats(0.0, 1.0),
    width=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_hybrid_preserves_every_nonzero(m, n, density, width,
                                                   seed):
    """Property: partition(h) loses no non-zero as long as the dense tail
    has capacity (here: capacity = m, can never overflow)."""
    rng = np.random.default_rng(seed)
    h = _sparse_h(rng, m, n, density)
    hyb = hybrid.hybrid_partition(h, ell_width=width, max_dense_rows=m)
    assert not bool(hyb["overflow"])
    np.testing.assert_allclose(np.asarray(hybrid.hybrid_densify(hyb)), h,
                               rtol=1e-6)
