"""AOT pipeline tests: manifest io-contract, HLO text validity, goldens."""

import json
import os
import shutil
import tempfile

import numpy as np
import pytest

from compile import aot, model as M
from compile.configs import PRESETS


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_preset("tiny", out)
    aot.dump_goldens(out)
    return out, manifest


def test_manifest_io_counts(built):
    _, man = built
    cfg = PRESETS["tiny"]
    n = len(M.param_specs(cfg))
    arts = man["artifacts"]
    assert len(arts["init"]["inputs"]) == 1
    assert len(arts["init"]["outputs"]) == n
    # train_step: 3n tensors + tokens + lr + l1 + step
    assert len(arts["train_step"]["inputs"]) == 3 * n + 4
    # outputs: 3n + loss, ce, l1, nnz, active, gnorm
    assert len(arts["train_step"]["outputs"]) == 3 * n + 6
    ts = arts["train_step"]
    assert ts["inputs"][3 * n]["dtype"] == "i32"
    assert ts["inputs"][3 * n]["shape"] == [cfg.train_batch, cfg.seq_len + 1]


def test_manifest_param_shapes_match_model(built):
    _, man = built
    cfg = PRESETS["tiny"]
    for entry, (name, shape) in zip(man["params"], M.param_specs(cfg)):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == shape


def test_hlo_text_is_parseable_hlo(built):
    out, man = built
    for key, art in man["artifacts"].items():
        path = os.path.join(out, "tiny", art["file"])
        with open(path) as f:
            head = f.read(200)
        assert "HloModule" in head, key


def test_goldens_consistency(built):
    out, _ = built
    with open(os.path.join(out, "goldens.json")) as f:
        g = json.load(f)
    m, k, n = g["m"], g["k"], g["n"]
    x = np.array(g["x"], np.float32).reshape(m, k)
    wg = np.array(g["wg"], np.float32).reshape(k, n)
    hg = np.maximum(x @ wg - g["gate_bias"], 0.0)
    h_nz = np.array(g["h_nz"], np.int64).reshape(m, n // g["tile_n"])
    # per-tile counts (clipped at slots) must match a recomputation
    slots = g["tile_n"] // g["comp"]
    for t in range(n // g["tile_n"]):
        blk = hg[:, t * g["tile_n"]:(t + 1) * g["tile_n"]]
        np.testing.assert_array_equal(
            np.minimum((blk > 0).sum(1), slots), h_nz[:, t])


def test_scan_k_semantics():
    """train_step8 == 8 sequential train_step calls."""
    import jax.numpy as jnp
    cfg = PRESETS["tiny"]
    params = M.init_params(cfg, 0)
    ms = [jnp.zeros_like(p) for p in params]
    vs = [jnp.zeros_like(p) for p in params]
    rng = np.random.default_rng(0)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(aot.SCAN_K, cfg.train_batch, cfg.seq_len + 1)),
        dtype=jnp.int32)
    lrs = jnp.full((aot.SCAN_K,), 1e-3)
    p8, m8, v8, loss8, *_ = aot.train_step_k(
        cfg, params, ms, vs, toks, lrs, 0.0, 0.0)
    p1, m1, v1 = params, ms, vs
    losses = []
    for i in range(aot.SCAN_K):
        p1, m1, v1, loss, *_ = M.train_step(
            cfg, p1, m1, v1, toks[i], 1e-3, 0.0, float(i))
        losses.append(float(loss))
    np.testing.assert_allclose(np.asarray(loss8), np.asarray(losses),
                               rtol=1e-4)
    # AdamW's m/(sqrt(v)+eps) amplifies f32 association noise when v ~ 0,
    # so parameter agreement after 8 steps is checked at a looser bound
    for a, b in zip(p8, p1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=3e-5)
