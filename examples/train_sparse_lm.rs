//! End-to-end training driver (the EXPERIMENTS.md §E2E run).
//!
//! Trains a sparse language model on the synthetic web corpus for a few
//! hundred steps through the full three-layer stack (rust coordinator ->
//! PJRT -> AOT'd jax train step), logging the loss curve and sparsity
//! trajectory, then evaluates the checkpoint on the 7-task suite and
//! reports everything — proving all layers compose on a real workload.
//!
//! Run: cargo run --release --example train_sparse_lm -- \
//!          [--preset s] [--steps 300] [--l1 0.6]

use repro::config::{default_paths, Args, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, Trainer};
use repro::data::bpe::Bpe;
use repro::data::corpus::CorpusSpec;
use repro::model::{FfnBackend, Model};
use repro::runtime::Runtime;
use repro::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let preset = args.get_or("preset", "s");
    let steps = args.get_usize("steps", 300)?;
    let l1 = args.get_f64("l1", 0.6)?;
    let paths = default_paths();
    let mut rt = Runtime::cpu()?;

    let cfg = TrainConfig {
        steps,
        l1_coeff: l1,
        warmup_steps: steps / 10,
        log_every: 20,
        ..TrainConfig::default()
    };
    let run_name = format!("e2e_{preset}");
    println!("== end-to-end run: preset {preset}, {steps} steps, l1={l1} ==");
    let mut tr = Trainer::new(&paths, &mut rt, &preset, cfg, &run_name)?;
    let res = tr.run(&CorpusSpec::default())?;

    println!("\nloss curve (every ~{} steps):", (steps / 12).max(1));
    for r in res.records.iter().step_by((steps / 12).max(1)) {
        println!(
            "  step {:>4}: loss {:.4}  ce {:.4}  nnz {:>6.1}  dead {:.1}%",
            r.step, r.loss, r.ce, r.mean_nnz, r.dead_frac * 100.0
        );
    }
    println!(
        "\nthroughput: {:.0} tokens/s over {:.1}s wall-clock",
        res.tokens_per_s, res.wallclock_s
    );
    println!("final per-layer nnz: {:?}", res.final_nnz_per_layer);

    // downstream evaluation through the rust inference engine
    let ck = Checkpoint::load(&res.run_dir.join("checkpoint.bin"))?;
    let model = Model::from_checkpoint(&ck, FfnBackend::Twell)?;
    let bpe =
        Bpe::from_json(&Json::read_file(&res.run_dir.join("tokenizer.json"))?)?;
    let results = repro::eval::evaluate(&model, &bpe, 40, 7)?;
    println!("\ndownstream tasks:");
    for r in &results {
        println!("  {:<24} {:.1}%", r.task, r.accuracy * 100.0);
    }
    println!(
        "  mean accuracy: {:.1}%",
        repro::eval::mean_accuracy(&results) * 100.0
    );
    println!("\nartifacts in {:?}", res.run_dir);
    Ok(())
}
