//! Quickstart: the whole three-layer pipeline in one minute.
//!
//!   1. train the `tiny` preset for a few dozen steps through the AOT'd
//!      PJRT train step (L2 artifacts, rust-driven),
//!   2. load the exported checkpoint into the rust inference engine,
//!   3. run the same prompt through the dense FFN baseline and the
//!      paper's two-kernel TwELL pipeline and check they agree,
//!   4. report the sparsity the model picked up.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use repro::config::{default_paths, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, Trainer};
use repro::data::corpus::CorpusSpec;
use repro::model::{FfnBackend, Model};
use repro::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let paths = default_paths();
    let mut rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());

    // -- 1. a short sparse training run ---------------------------------
    let cfg = TrainConfig {
        steps: 48,
        l1_coeff: 0.3, // mild regularization (scaled grid; EXPERIMENTS.md)
        warmup_steps: 8,
        log_every: 16,
        ..TrainConfig::default()
    };
    let mut tr = Trainer::new(&paths, &mut rt, "tiny", cfg, "quickstart")?;
    let corpus = CorpusSpec { n_docs: 400, ..CorpusSpec::default() };
    let res = tr.run(&corpus)?;
    println!(
        "trained tiny preset: loss {:.3} -> {:.3} ({:.0} tok/s)",
        res.records.first().map(|r| r.loss).unwrap_or(0.0),
        res.records.last().map(|r| r.loss).unwrap_or(0.0),
        res.tokens_per_s
    );

    // -- 2. load the checkpoint into the rust engine --------------------
    let ck = Checkpoint::load(&res.run_dir.join("checkpoint.bin"))?;
    let dense = Model::from_checkpoint(&ck, FfnBackend::Dense)?;
    let sparse = Model::from_checkpoint(&ck, FfnBackend::Twell)?;

    // -- 3. dense vs TwELL parity on a real prompt ----------------------
    let bpe = repro::data::bpe::Bpe::from_json(
        &repro::util::json::Json::read_file(
            &res.run_dir.join("tokenizer.json"),
        )?,
    )?;
    let prompt = bpe.encode("topic geography : the river");
    let (ld, sd) = dense.forward(&prompt, 1, prompt.len());
    let (ls, ss) = sparse.forward(&prompt, 1, prompt.len());
    println!(
        "dense vs TwELL logits rel err: {:.2e} (must be ~0)",
        ls.rel_err(&ld)
    );
    assert!(ls.rel_err(&ld) < 1e-3);

    // -- 4. the sparsity the model learned -------------------------------
    for l in 0..sparse.cfg.n_layers {
        println!(
            "layer {l}: avg gate nnz {:.1} / {} neurons",
            ss.avg_nnz(l),
            sparse.cfg.d_ff
        );
    }
    let _ = sd;
    println!("generated: {:?}", bpe.decode(&sparse.generate(&prompt, 12)));
    println!("quickstart OK");
    Ok(())
}
