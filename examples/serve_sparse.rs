//! Serving demo: load a trained checkpoint, start the continuous-batching
//! server, fire a wave of concurrent requests and report
//! latency/throughput for both FFN backends and a sweep of slot counts —
//! the serving-side view of table 1's forward-execution column, now with
//! the TwELL pipeline seeing multi-row activations during decode.
//!
//! Run: cargo run --release --example serve_sparse -- \
//!        [--run e2e_s] [--slots 8] [--requests 24] [--max-new 12] \
//!        [--kv-blocks 128] [--kv-block-size 16] [--prefill-chunk 16] \
//!        [--route-density 0.25] [--prefix-cache on|off] \
//!        [--temperature 0.8] [--top-k 40] [--top-p 0.95] [--seed 0] \
//!        [--threads N] [--shards 1] \
//!        [--max-queue 0] [--deadline-ms 0]
//! (trains a quick tiny model if the run does not exist yet;
//! temperature 0 — the default — decodes greedily, request i samples
//! with seed `--seed + i` so runs stay reproducible, --threads pins
//! the kernel worker pool before first use — it is the TOTAL budget,
//! split evenly across --shards engine shards — and --route-density
//! sets the union-density threshold for batch-contextual FFN routing
//! on the twell engine — 0 disables the routed path)

use std::time::{Duration, Instant};

use repro::config::{default_paths, Args, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, Trainer};
use repro::data::bpe::Bpe;
use repro::data::corpus::CorpusSpec;
use repro::model::sample::SamplingParams;
use repro::model::{FfnBackend, Model};
use repro::runtime::Runtime;
use repro::serve::{
    ServeMetrics, ServeMode, ServePolicy, Server, SubmitOptions,
};
use repro::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let shards = args.get_usize("shards", 1)?.max(1);
    // pin the kernel worker pool before the first kernel call;
    // --threads is the total budget, divided evenly across shards
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 {
        repro::sparse::par::set_threads(
            repro::sparse::par::threads_per_shard(threads, shards),
        );
    } else if shards > 1 {
        let auto = repro::sparse::par::num_threads();
        repro::sparse::par::set_threads(
            repro::sparse::par::threads_per_shard(auto, shards),
        );
    }
    let run = args.get_or("run", "serve_demo");
    let n_requests = args.get_usize("requests", 24)?;
    let max_new = args.get_usize("max-new", 12)?;
    let slots = args.get_usize("slots", 8)?;
    // paged KV pool: shared by all slots, sized in blocks
    let kv_block_size = args.get_usize("kv-block-size", 16)?;
    let kv_blocks = args.get_usize("kv-blocks", 128)?;
    // prompt tokens fed per prefilling slot per engine iteration;
    // defaults to one KV block
    let prefill_chunk = args.get_usize("prefill-chunk", kv_block_size)?;
    // union-density threshold for routed decode FFN (twell backend)
    let route_density = args.get_f64("route-density", 0.25)? as f32;
    // overload QoS: bounded admission queue (0 = unbounded) and an
    // optional per-request deadline measured from submit (0 = none)
    let max_queue = args.get_usize("max-queue", 0)?;
    let deadline_ms = args.get_f64("deadline-ms", 0.0)?;
    let opts_for = || SubmitOptions {
        deadline: (deadline_ms > 0.0).then(|| {
            Instant::now() + Duration::from_secs_f64(deadline_ms / 1e3)
        }),
        max_queue_wait: None,
    };
    // copy-on-write prefix caching in the paged KV pool — token
    // streams are bit-identical on or off (placement only)
    let prefix_cache = match args.get_or("prefix-cache", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => anyhow::bail!("unknown --prefix-cache value {other:?}"),
    };
    // per-request sampling (temperature 0 = greedy argmax)
    let base_params = SamplingParams {
        temperature: args.get_f64("temperature", 0.0)? as f32,
        top_k: args.get_usize("top-k", 0)?,
        top_p: args.get_f64("top-p", 1.0)? as f32,
        seed: args.get_usize("seed", 0)? as u64,
    };
    base_params.validate()?;
    let params_for = |i: usize| SamplingParams {
        seed: base_params.seed.wrapping_add(i as u64),
        ..base_params
    };
    println!(
        "kernel worker pool: {} threads/shard x {shards} shards",
        repro::sparse::par::num_threads()
    );
    let paths = default_paths();
    let dir = paths.run_dir(&run);
    if !dir.join("checkpoint.bin").exists() {
        println!("run {run:?} missing — training a quick tiny model ...");
        let mut rt = Runtime::cpu()?;
        let cfg = TrainConfig { steps: 48, l1_coeff: 0.3, warmup_steps: 8,
                                ..TrainConfig::default() };
        Trainer::new(&paths, &mut rt, "tiny", cfg, &run)?
            .run(&CorpusSpec { n_docs: 400, ..CorpusSpec::default() })?;
    }
    let ck = Checkpoint::load(&dir.join("checkpoint.bin"))?;
    let bpe = Bpe::from_json(&Json::read_file(&dir.join("tokenizer.json"))?)?;
    let prompts = [
        "topic geography : the river",
        "topic chemistry : the acid reacts",
        "source : www nih",
        "topic history : the empire",
    ];

    for (label, backend) in
        [("dense", FfnBackend::Dense), ("twell", FfnBackend::Twell)]
    {
        // sequential baseline vs the continuous engine at --slots
        for (mode, eff_slots) in [
            (ServeMode::Sequential, slots),
            (ServeMode::Continuous, 1),
            (ServeMode::Continuous, slots),
        ] {
            let model = Model::from_checkpoint(&ck, backend)?;
            let policy = ServePolicy {
                slots: eff_slots,
                max_wait: Duration::from_millis(5),
                kv_block_size,
                kv_blocks,
                prefill_chunk,
                route_density,
                prefix_cache,
                max_queue,
                mode,
                shards,
            };
            let server = Server::start(model, policy);
            let t0 = Instant::now();
            let rxs: Vec<_> = (0..n_requests)
                .map(|i| {
                    server
                        .submit_opts(
                            bpe.encode(prompts[i % prompts.len()]),
                            max_new,
                            params_for(i),
                            opts_for(),
                        )
                        .map(|(_, rx)| rx)
                        .map_err(anyhow::Error::new)
                })
                .collect::<anyhow::Result<_>>()?;
            let mut metrics = ServeMetrics::default();
            for rx in rxs {
                metrics.record(rx.recv()?);
            }
            let wall = t0.elapsed().as_secs_f64();
            let per_shard = server.shard_stats();
            let stats = server.stats();
            println!(
                "{label:>6} {:<22} {n_requests} reqs: p50 {:.1} ms, \
                 p95 {:.1} ms, ttft p50 {:.1} ms, {:.0} tok/s \
                 ({} backfills, {} prefill chunks, ffn {} routed / \
                 {} fallback, mean union density {:.3}, \
                 queue peak {}, {} prefix hits / {} blocks shared, \
                 peak {} KV blocks)",
                format!("{mode:?}/{eff_slots} slots"),
                metrics.p50_ms(),
                metrics.p95_ms(),
                metrics.p50_first_token_ms(),
                metrics.throughput_tok_s(wall),
                stats.backfilled,
                stats.prefill_chunks,
                stats.ffn_routed,
                stats.ffn_fallback,
                stats.mean_union_density(),
                stats.queue_peak,
                stats.prefix_hits,
                stats.prefix_blocks_shared,
                stats.kv_blocks_peak,
            );
            if max_queue > 0 || deadline_ms > 0.0 {
                println!(
                    "        overload: {} shed at deadline, {} deadline \
                     aborts, {} busy-shed, {} queue rejections, \
                     {} shard restarts",
                    stats.shed_deadline,
                    stats.deadline_aborts,
                    stats.shed_busy,
                    stats.queue_rejections,
                    stats.shard_restarts,
                );
            }
            if shards > 1 {
                for (i, st) in per_shard.iter().enumerate() {
                    println!(
                        "        shard {i}: {} admissions \
                         ({} backfilled), {} steps, max active {}",
                        st.admissions,
                        st.backfilled,
                        st.steps,
                        st.max_active,
                    );
                }
            }
            server.shutdown();
        }
    }

    // per-token streaming demo on the twell engine
    let model = Model::from_checkpoint(&ck, FfnBackend::Twell)?;
    let server = Server::start(model, ServePolicy {
        slots,
        max_wait: Duration::from_millis(5),
        kv_block_size,
        kv_blocks,
        prefill_chunk,
        route_density,
        prefix_cache,
        max_queue,
        mode: ServeMode::Continuous,
        shards,
    });
    let (_, tok_rx, done_rx) = server.submit_streaming_sampled(
        bpe.encode(prompts[0]),
        max_new,
        params_for(0),
    )?;
    print!("streamed:");
    for t in tok_rx.iter() {
        print!(" {}", bpe.decode(&[t.token]).trim());
    }
    println!();
    let c = done_rx.recv()?;
    println!("completion: {:?}", bpe.decode(&c.tokens));
    server.shutdown();
    Ok(())
}
