//! Serving demo: load a trained checkpoint, start the dynamic-batching
//! server with the TwELL FFN backend, fire a wave of concurrent requests
//! and report latency/throughput (the serving-side view of table 1's
//! forward-execution column).
//!
//! Run: cargo run --release --example serve_sparse -- [--run e2e_s]
//! (trains a quick tiny model if the run does not exist yet)

use std::time::Instant;

use repro::config::{default_paths, Args, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, Trainer};
use repro::data::bpe::Bpe;
use repro::data::corpus::CorpusSpec;
use repro::model::{FfnBackend, Model};
use repro::runtime::Runtime;
use repro::serve::{BatchPolicy, ServeMetrics, Server};
use repro::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let run = args.get_or("run", "serve_demo");
    let n_requests = args.get_usize("requests", 24)?;
    let max_new = args.get_usize("max-new", 12)?;
    let paths = default_paths();
    let dir = paths.run_dir(&run);
    if !dir.join("checkpoint.bin").exists() {
        println!("run {run:?} missing — training a quick tiny model ...");
        let mut rt = Runtime::cpu()?;
        let cfg = TrainConfig { steps: 48, l1_coeff: 0.3, warmup_steps: 8,
                                ..TrainConfig::default() };
        Trainer::new(&paths, &mut rt, "tiny", cfg, &run)?
            .run(&CorpusSpec { n_docs: 400, ..CorpusSpec::default() })?;
    }
    let ck = Checkpoint::load(&dir.join("checkpoint.bin"))?;
    let bpe = Bpe::from_json(&Json::read_file(&dir.join("tokenizer.json"))?)?;

    for (label, backend) in
        [("dense", FfnBackend::Dense), ("twell", FfnBackend::Twell)]
    {
        let model = Model::from_checkpoint(&ck, backend)?;
        let server = Server::start(model, BatchPolicy::default());
        let prompts = [
            "topic geography : the river",
            "topic chemistry : the acid reacts",
            "source : www nih",
            "topic history : the empire",
        ];
        let t0 = Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|i| {
                server
                    .submit(bpe.encode(prompts[i % prompts.len()]), max_new)
                    .1
            })
            .collect();
        let mut metrics = ServeMetrics::default();
        for rx in rxs {
            metrics.record(rx.recv()?);
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{label:>6}: {n_requests} reqs, p50 {:.1} ms, p99 {:.1} ms, \
             {:.0} tok/s",
            metrics.p50_ms(),
            metrics.p99_ms(),
            metrics.throughput_tok_s(wall)
        );
        if label == "twell" {
            let sample = &metrics.completions[0];
            println!("   sample completion: {:?}",
                     bpe.decode(&sample.tokens));
        }
        server.shutdown();
    }
    Ok(())
}
