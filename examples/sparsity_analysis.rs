//! Sparsity analysis driver (figures 6 & 7): per-layer nnz statistics,
//! per-layer sparse-vs-dense FFN speedup attribution with Pearson
//! correlation, and token/position sparsity profiles, on a trained run.
//!
//! Run: cargo run --release --example sparsity_analysis -- [--run e2e_s]
//! (trains a quick tiny model if the run does not exist yet)

use repro::config::{default_paths, Args, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, Trainer};
use repro::data::bpe::Bpe;
use repro::data::corpus::CorpusSpec;
use repro::runtime::{ModelBundle, Runtime, TrainState};
use repro::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let run = args.get_or("run", "analysis_demo");
    let paths = default_paths();
    let dir = paths.run_dir(&run);
    let mut rt = Runtime::cpu()?;
    if !dir.join("checkpoint.bin").exists() {
        println!("run {run:?} missing — training a quick sparse tiny model");
        let cfg = TrainConfig { steps: 64, l1_coeff: 0.5, warmup_steps: 8,
                                ..TrainConfig::default() };
        Trainer::new(&paths, &mut rt, "tiny", cfg, &run)?
            .run(&CorpusSpec { n_docs: 600, ..CorpusSpec::default() })?;
    }
    let ck = Checkpoint::load(&dir.join("checkpoint.bin"))?;
    let bundle = ModelBundle::open(&paths.artifacts, &ck.config.name)?;
    let params: Vec<Vec<f32>> =
        ck.params.iter().map(|(_, _, d)| d.clone()).collect();
    let state = TrainState::from_params(&bundle, &params)?;
    let bpe = Bpe::from_json(&Json::read_file(&dir.join("tokenizer.json"))?)?;

    println!("== figure 6: layer statistics + speedup attribution ==");
    repro::analysis::analyze_layers(&bundle, &mut rt, &state, &ck, &dir)?;
    println!("\n== figure 7: token / position sparsity profiles ==");
    repro::analysis::analyze_tokens(&bundle, &mut rt, &state, &bpe, &dir)?;
    println!("\nresults saved next to the run: {dir:?}");
    Ok(())
}
