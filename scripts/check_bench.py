#!/usr/bin/env python3
"""Validate the serve_throughput bench report (BENCH_serve_throughput.json).

CI runs the bench in --smoke mode and then this script; developers can
run it locally the same way:

    cargo bench --bench serve_throughput -- --smoke
    python3 scripts/check_bench.py [path-to-report.json]

The routing A/B sweep must land in the persisted report with a measured
union density and a dispatch label on every row, for all three paths
(routed union-gather, TwELL row fallback, dense baseline) — the
trajectory tooling indexes on these.  The shard sweep must cover shard
counts {1, 2, 4} with a queue_peak gauge on every row.  The
prefix-cache sweep must carry the sharing counters on every row and
show the 80%-shared trace actually winning: TTFT and the peak block
footprint strictly better with the cache on, hits only when it is on.
The overload sweep must cover shed on and off, carry the shedding
counters on every row, and show the QoS layer earning its keep:
goodput and p99 TTFT strictly better with shedding on, deadline
shedding provably engaged, and nothing shed when the queue is
unbounded and deadline-free.
"""
import json
import sys


def check(report_path):
    with open(report_path) as f:
        report = json.load(f)
    rows = [r for r in report["rows"] if r.get("section") == "decode_routing"]
    assert rows, "no section=decode_routing rows in the report"
    for r in rows:
        assert "union_density" in r, f"missing union_density: {r}"
        assert "dispatch" in r, f"missing dispatch: {r}"
    paths = {r["path"] for r in rows}
    want = {"routed", "twell-row", "dense"}
    assert want <= paths, f"paths {paths} missing {want - paths}"
    print(f"{len(rows)} decode_routing rows ok; paths: {sorted(paths)}")

    srows = [r for r in report["rows"] if r.get("section") == "shard_sweep"]
    assert srows, "no section=shard_sweep rows in the report"
    for r in srows:
        assert "shards" in r, f"missing shards: {r}"
        assert "queue_peak" in r, f"missing queue_peak: {r}"
    shard_counts = {int(r["shards"]) for r in srows}
    want_shards = {1, 2, 4}
    assert want_shards <= shard_counts, (
        f"shard counts {shard_counts} missing {want_shards - shard_counts}"
    )
    print(f"{len(srows)} shard_sweep rows ok; shards: {sorted(shard_counts)}")

    prows = [r for r in report["rows"] if r.get("section") == "prefix_cache"]
    assert prows, "no section=prefix_cache rows in the report"
    for r in prows:
        for field in ("prefix", "prefix_hits", "prefix_blocks_shared",
                      "cow_copies", "kv_blocks_peak", "first_token_ms"):
            assert field in r, f"missing {field}: {r}"
    by_prefix = {r["prefix"]: r for r in prows}
    assert set(by_prefix) == {"on", "off"}, (
        f"expected one on and one off row, got {sorted(by_prefix)}"
    )
    on, off = by_prefix["on"], by_prefix["off"]
    assert on["prefix_hits"] > 0, f"sharing never engaged: {on}"
    assert off["prefix_hits"] == 0, f"hits counted with the cache off: {off}"
    assert off["prefix_blocks_shared"] == 0 and off["cow_copies"] == 0, (
        f"sharing work counted with the cache off: {off}"
    )
    assert on["first_token_ms"] < off["first_token_ms"], (
        "the 80%-shared trace must improve TTFT: "
        f"on {on['first_token_ms']} >= off {off['first_token_ms']}"
    )
    assert on["kv_blocks_peak"] < off["kv_blocks_peak"], (
        "sharing must shrink the peak block footprint: "
        f"on {on['kv_blocks_peak']} >= off {off['kv_blocks_peak']}"
    )
    print(
        f"{len(prows)} prefix_cache rows ok; ttft on "
        f"{on['first_token_ms']:.1f} ms vs off "
        f"{off['first_token_ms']:.1f} ms, peak blocks "
        f"{int(on['kv_blocks_peak'])} vs {int(off['kv_blocks_peak'])}"
    )

    orows = [r for r in report["rows"] if r.get("section") == "overload"]
    assert orows, "no section=overload rows in the report"
    for r in orows:
        for field in ("shed", "goodput_tok_s", "p99_ttft_ms", "served",
                      "shed_busy", "shed_deadline", "queue_rejections",
                      "deadline_aborts", "deadline_ms"):
            assert field in r, f"missing {field}: {r}"
    by_shed = {r["shed"]: r for r in orows}
    assert set(by_shed) == {"on", "off"}, (
        f"expected one shed=on and one shed=off row, got {sorted(by_shed)}"
    )
    on, off = by_shed["on"], by_shed["off"]
    assert on["served"] > 0 and off["served"] > 0, (
        f"an overload wave served nothing: on {on['served']}, "
        f"off {off['served']}"
    )
    assert on["shed_deadline"] > 0, (
        f"deadline shedding never engaged with the QoS layer on: {on}"
    )
    assert on["served"] < on["requests"], (
        f"shed=on served the whole burst — no overload exercised: {on}"
    )
    for field in ("shed_busy", "shed_deadline", "queue_rejections",
                  "deadline_aborts"):
        assert off[field] == 0, (
            f"{field} counted with shedding off: {off}"
        )
    assert on["goodput_tok_s"] > off["goodput_tok_s"], (
        "shedding must improve within-deadline goodput under overload: "
        f"on {on['goodput_tok_s']} <= off {off['goodput_tok_s']}"
    )
    assert on["p99_ttft_ms"] < off["p99_ttft_ms"], (
        "shedding must improve p99 TTFT under overload: "
        f"on {on['p99_ttft_ms']} >= off {off['p99_ttft_ms']}"
    )
    print(
        f"{len(orows)} overload rows ok; goodput on "
        f"{on['goodput_tok_s']:.0f} vs off {off['goodput_tok_s']:.0f} "
        f"tok/s, p99 ttft on {on['p99_ttft_ms']:.1f} ms vs off "
        f"{off['p99_ttft_ms']:.1f} ms, shed "
        f"{int(on['shed_busy'])} busy / {int(on['shed_deadline'])} "
        f"deadline / {int(on['queue_rejections'])} rejected"
    )


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve_throughput.json")
