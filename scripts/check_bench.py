#!/usr/bin/env python3
"""Validate the serve_throughput bench report (BENCH_serve_throughput.json).

CI runs the bench in --smoke mode and then this script; developers can
run it locally the same way:

    cargo bench --bench serve_throughput -- --smoke
    python3 scripts/check_bench.py [path-to-report.json]

The routing A/B sweep must land in the persisted report with a measured
union density and a dispatch label on every row, for all three paths
(routed union-gather, TwELL row fallback, dense baseline) — the
trajectory tooling indexes on these.
"""
import json
import sys


def check(report_path):
    with open(report_path) as f:
        report = json.load(f)
    rows = [r for r in report["rows"] if r.get("section") == "decode_routing"]
    assert rows, "no section=decode_routing rows in the report"
    for r in rows:
        assert "union_density" in r, f"missing union_density: {r}"
        assert "dispatch" in r, f"missing dispatch: {r}"
    paths = {r["path"] for r in rows}
    want = {"routed", "twell-row", "dense"}
    assert want <= paths, f"paths {paths} missing {want - paths}"
    print(f"{len(rows)} decode_routing rows ok; paths: {sorted(paths)}")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve_throughput.json")
