#!/usr/bin/env python3
"""Validate the serve_throughput bench report (BENCH_serve_throughput.json).

CI runs the bench in --smoke mode and then this script; developers can
run it locally the same way:

    cargo bench --bench serve_throughput -- --smoke
    python3 scripts/check_bench.py [path-to-report.json]

The routing A/B sweep must land in the persisted report with a measured
union density and a dispatch label on every row, for all three paths
(routed union-gather, TwELL row fallback, dense baseline) — the
trajectory tooling indexes on these.  The shard sweep must cover shard
counts {1, 2, 4} with a queue_peak gauge on every row.
"""
import json
import sys


def check(report_path):
    with open(report_path) as f:
        report = json.load(f)
    rows = [r for r in report["rows"] if r.get("section") == "decode_routing"]
    assert rows, "no section=decode_routing rows in the report"
    for r in rows:
        assert "union_density" in r, f"missing union_density: {r}"
        assert "dispatch" in r, f"missing dispatch: {r}"
    paths = {r["path"] for r in rows}
    want = {"routed", "twell-row", "dense"}
    assert want <= paths, f"paths {paths} missing {want - paths}"
    print(f"{len(rows)} decode_routing rows ok; paths: {sorted(paths)}")

    srows = [r for r in report["rows"] if r.get("section") == "shard_sweep"]
    assert srows, "no section=shard_sweep rows in the report"
    for r in srows:
        assert "shards" in r, f"missing shards: {r}"
        assert "queue_peak" in r, f"missing queue_peak: {r}"
    shard_counts = {int(r["shards"]) for r in srows}
    want_shards = {1, 2, 4}
    assert want_shards <= shard_counts, (
        f"shard counts {shard_counts} missing {want_shards - shard_counts}"
    )
    print(f"{len(srows)} shard_sweep rows ok; shards: {sorted(shard_counts)}")


if __name__ == "__main__":
    check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_serve_throughput.json")
