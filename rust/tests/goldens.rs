//! Cross-language lockstep: the rust TwELL/hybrid kernels must agree with
//! the python reference oracle (python/compile/kernels/ref.py) on the
//! golden vectors dumped by `make artifacts` (aot.py --goldens).
//!
//! Skips when artifacts/goldens.json has not been built.

use repro::config::default_paths;
use repro::sparse::dense;
use repro::sparse::fused::fused_up_down;
use repro::sparse::hybrid::HybridMatrix;
use repro::sparse::twell::gate_matmul_twell;
use repro::tensor::Mat;
use repro::util::json::Json;

struct Golden {
    m: usize,
    k: usize,
    n: usize,
    tile_n: usize,
    comp: usize,
    x: Mat,
    wg_biased: Mat,
    wu: Mat,
    wd: Mat,
    g: Json,
}

fn load() -> Option<Golden> {
    let path = default_paths().artifacts.join("goldens.json");
    if !path.exists() {
        eprintln!("skipping: {path:?} not built (run `make artifacts`)");
        return None;
    }
    let g = Json::read_file(&path).unwrap();
    let m = g.get("m").unwrap().as_usize().unwrap();
    let k = g.get("k").unwrap().as_usize().unwrap();
    let n = g.get("n").unwrap().as_usize().unwrap();
    let bias = g.get("gate_bias").unwrap().as_f64().unwrap() as f32;
    let x = Mat::from_vec(m, k, g.get("x").unwrap().f32_vec().unwrap());
    let wg = Mat::from_vec(k, n, g.get("wg").unwrap().f32_vec().unwrap());
    let wu = Mat::from_vec(k, n, g.get("wu").unwrap().f32_vec().unwrap());
    let wd = Mat::from_vec(n, k, g.get("wd").unwrap().f32_vec().unwrap());
    // python computed hg = relu(x @ wg - bias); fold the bias into an
    // augmented gate weight via an extra constant input column
    let mut x_aug = Mat::zeros(m, k + 1);
    for r in 0..m {
        x_aug.row_mut(r)[..k].copy_from_slice(x.row(r));
        x_aug.row_mut(r)[k] = 1.0;
    }
    let mut wg_aug = Mat::zeros(k + 1, n);
    for kk in 0..k {
        wg_aug.row_mut(kk).copy_from_slice(wg.row(kk));
    }
    for c in 0..n {
        *wg_aug.at_mut(k, c) = -bias;
    }
    Some(Golden {
        m,
        k,
        n,
        tile_n: g.get("tile_n").unwrap().as_usize().unwrap(),
        comp: g.get("comp").unwrap().as_usize().unwrap(),
        x: x_aug,
        wg_biased: wg_aug,
        wu,
        wd,
        g,
    })
}

#[test]
fn twell_pack_matches_python_reference() {
    let Some(gd) = load() else { return };
    let tw = gate_matmul_twell(&gd.x, &gd.wg_biased, gd.tile_n, gd.comp);
    let h_v = gd.g.get("h_v").unwrap().f32_vec().unwrap();
    let h_i = gd.g.get("h_i").unwrap().i32_vec().unwrap();
    let h_nz = gd.g.get("h_nz").unwrap().i32_vec().unwrap();
    assert_eq!(tw.values.len(), h_v.len());
    for (i, (a, b)) in tw.values.iter().zip(&h_v).enumerate() {
        assert!((a - b).abs() < 1e-4, "value[{i}]: {a} vs {b}");
    }
    for (i, (a, b)) in tw.indices.iter().zip(&h_i).enumerate() {
        assert_eq!(*a as i32, *b, "index[{i}]");
    }
    for (i, (a, b)) in tw.nnz.iter().zip(&h_nz).enumerate() {
        assert_eq!(*a as i32, *b, "nnz[{i}]");
    }
}

#[test]
fn fused_ffn_matches_python_reference() {
    let Some(gd) = load() else { return };
    let tw = gate_matmul_twell(&gd.x, &gd.wg_biased, gd.tile_n, gd.comp);
    // the fused kernel consumes the ORIGINAL x (k columns), as python did
    let mut x = Mat::zeros(gd.m, gd.k);
    for r in 0..gd.m {
        x.row_mut(r).copy_from_slice(&gd.x.row(r)[..gd.k]);
    }
    let y = fused_up_down(&x, &tw, &gd.wu.transpose(), &gd.wd);
    let y_ref =
        Mat::from_vec(gd.m, gd.k, gd.g.get("y_fused").unwrap().f32_vec().unwrap());
    assert!(y.rel_err(&y_ref) < 1e-3, "rel err {}", y.rel_err(&y_ref));
}

#[test]
fn hybrid_partition_and_matmul_match_python_reference() {
    let Some(gd) = load() else { return };
    // rebuild hg densely exactly as python did
    let hg = dense::matmul_relu(&gd.x, &gd.wg_biased);
    let ell_width = gd.g.get("ell_width").unwrap().as_usize().unwrap();
    let max_rows = gd.g.get("max_dense_rows").unwrap().as_usize().unwrap();
    let hyb = HybridMatrix::from_dense(&hg, ell_width, max_rows);
    let row_nnz = gd.g.get("row_nnz").unwrap().i32_vec().unwrap();
    let is_dense = gd.g.get("is_dense").unwrap().i32_vec().unwrap();
    for r in 0..gd.m {
        assert_eq!(hyb.row_nnz[r] as i32, row_nnz[r], "row {r}");
        assert_eq!(hyb.is_dense[r] as i32, is_dense[r], "route {r}");
    }
    let ell_val = gd.g.get("ell_val").unwrap().f32_vec().unwrap();
    for r in 0..gd.m {
        if !hyb.is_dense[r] {
            for z in 0..hyb.row_nnz[r] as usize {
                let got = hyb.ell_val[r * ell_width + z];
                let want = ell_val[r * ell_width + z];
                assert!((got - want).abs() < 1e-4, "({r},{z})");
            }
        }
    }
    let w2 = Mat::from_vec(gd.n, gd.k, gd.g.get("w2").unwrap().f32_vec().unwrap());
    let y = hyb.matmul(&w2);
    let y_ref = Mat::from_vec(
        gd.m, gd.k, gd.g.get("y_hybrid").unwrap().f32_vec().unwrap(),
    );
    assert!(y.rel_err(&y_ref) < 1e-3, "rel err {}", y.rel_err(&y_ref));
}
