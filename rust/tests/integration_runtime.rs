//! Integration: AOT artifacts -> PJRT runtime -> training loop.
//!
//! These tests require `make artifacts` to have produced the `tiny`
//! preset; they skip (with a note) when artifacts are absent so
//! `cargo test` stays usable before the python step.

use repro::config::default_paths;
use repro::data::corpus::CorpusSpec;
use repro::data::loader::{Dataset, Loader};
use repro::runtime::{lit_f32, ModelBundle, Runtime, TrainState};

fn bundle_or_skip() -> Option<(ModelBundle, Runtime)> {
    let paths = default_paths();
    if !paths.manifest("tiny").exists() {
        eprintln!("skipping: artifacts/tiny not built (run `make artifacts`)");
        return None;
    }
    let bundle = ModelBundle::open(&paths.artifacts, "tiny").unwrap();
    let rt = Runtime::cpu().unwrap();
    Some((bundle, rt))
}

#[test]
fn init_produces_manifest_shapes() {
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let params = bundle.init(&mut rt, 0).unwrap();
    assert_eq!(params.len(), bundle.manifest.params.len());
    for (lit, spec) in params.iter().zip(&bundle.manifest.params) {
        let n: usize = spec.shape.iter().product();
        assert_eq!(lit.element_count(), n, "{}", spec.name);
    }
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let a = bundle.init(&mut rt, 7).unwrap();
    let b = bundle.init(&mut rt, 7).unwrap();
    let c = bundle.init(&mut rt, 8).unwrap();
    let av = a[0].to_vec::<f32>().unwrap();
    let bv = b[0].to_vec::<f32>().unwrap();
    let cv = c[0].to_vec::<f32>().unwrap();
    assert_eq!(av, bv);
    assert_ne!(av, cv);
}

#[test]
fn train_loop_loss_decreases_and_scan_matches() {
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let cfg = bundle.manifest.config.clone();
    let spec = CorpusSpec { n_docs: 120, seed: 3, ..CorpusSpec::default() };
    let (ds, _bpe) = Dataset::synthetic(&spec, cfg.vocab_size);
    let mut loader = Loader::new(&ds, cfg.train_batch, cfg.seq_len, 0);

    let mut st = TrainState::init(&bundle, &mut rt, 1).unwrap();
    let mut first = None;
    let mut last = 0f32;
    for _ in 0..30 {
        let batch = loader.next_batch();
        let stats = st.step(&bundle, &mut rt, &batch, 3e-3, 0.0).unwrap();
        assert!(stats.loss.is_finite());
        assert_eq!(stats.nnz.len(), cfg.n_layers);
        first.get_or_insert(stats.loss);
        last = stats.loss;
    }
    assert!(
        last < first.unwrap() * 0.9,
        "loss did not decrease: {} -> {last}",
        first.unwrap()
    );

    // train_step8 must agree with 8 sequential steps (same stream)
    let mut l1 = Loader::new(&ds, cfg.train_batch, cfg.seq_len, 9);
    let mut l2 = Loader::new(&ds, cfg.train_batch, cfg.seq_len, 9);
    let mut a = TrainState::init(&bundle, &mut rt, 2).unwrap();
    let mut b = TrainState::init(&bundle, &mut rt, 2).unwrap();
    let k = bundle.manifest.scan_k;
    let lrs: Vec<f32> = (0..k).map(|i| 1e-3 + i as f32 * 1e-5).collect();
    let toks = l1.next_batches(k);
    let stats_k = a.step_k(&bundle, &mut rt, &toks, &lrs, 0.0).unwrap();
    let mut seq_losses = Vec::new();
    for lr in &lrs {
        let batch = l2.next_batch();
        let s = b.step(&bundle, &mut rt, &batch, *lr, 0.0).unwrap();
        seq_losses.push(s.loss);
    }
    for (ks, ss) in stats_k.iter().zip(&seq_losses) {
        assert!(
            (ks.loss - ss).abs() < 1e-3 * ss.abs().max(1.0),
            "scan {} vs seq {}",
            ks.loss,
            ss
        );
    }
}

#[test]
fn score_and_forward_stats_shapes() {
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let cfg = bundle.manifest.config.clone();
    let st = TrainState::init(&bundle, &mut rt, 3).unwrap();
    let toks: Vec<i32> = (0..cfg.score_batch * (cfg.seq_len + 1))
        .map(|i| (i % cfg.vocab_size) as i32)
        .collect();
    let (logp, nnz) = st.score(&bundle, &mut rt, &toks).unwrap();
    assert_eq!(logp.len(), cfg.score_batch * cfg.seq_len);
    assert_eq!(nnz.len(), cfg.n_layers);
    assert!(logp.iter().all(|&v| v <= 0.0));
    // near-uniform logprob at init
    let mean: f32 = logp.iter().sum::<f32>() / logp.len() as f32;
    assert!((mean + (cfg.vocab_size as f32).ln()).abs() < 1.0, "{mean}");

    let toks2: Vec<i32> = (0..cfg.score_batch * cfg.seq_len)
        .map(|i| (i % cfg.vocab_size) as i32)
        .collect();
    let stats = st.forward_stats(&bundle, &mut rt, &toks2).unwrap();
    assert_eq!(stats.len(), cfg.n_layers * cfg.score_batch * cfg.seq_len);
    assert!(stats.iter().all(|&v| (0.0..=cfg.d_ff as f32).contains(&v)));
}

#[test]
fn reinit_touches_only_dead_gate_columns() {
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let cfg = bundle.manifest.config.clone();
    let mut st = TrainState::init(&bundle, &mut rt, 4).unwrap();
    let before = st.params_f32().unwrap();
    let mut active = vec![1f32; cfg.n_layers * cfg.d_ff];
    active[3] = 0.0; // layer 0, neuron 3 dead
    st.reinit(&bundle, &mut rt, &active, 11, 0.1).unwrap();
    let after = st.params_f32().unwrap();
    let names: Vec<&str> = bundle
        .manifest
        .params
        .iter()
        .map(|p| p.name.as_str())
        .collect();
    let wg0 = names.iter().position(|n| *n == "layer0.wg").unwrap();
    let d = cfg.d_model;
    let f = cfg.d_ff;
    let mut changed_cols = std::collections::BTreeSet::new();
    for r in 0..d {
        for c in 0..f {
            if before[wg0][r * f + c] != after[wg0][r * f + c] {
                changed_cols.insert(c);
            }
        }
    }
    assert_eq!(changed_cols.into_iter().collect::<Vec<_>>(), vec![3]);
    for (i, name) in names.iter().enumerate() {
        if i != wg0 {
            assert_eq!(before[i], after[i], "{name} must be untouched");
        }
    }
}

#[test]
fn pallas_twell_ffn_artifact_runs_and_matches_rust_kernels() {
    // the L1 -> AOT -> rust composition proof: the Pallas TwELL FFN
    // artifact must agree with the rust sparse kernels on the same data
    let Some((bundle, mut rt)) = bundle_or_skip() else { return };
    let cfg = bundle.manifest.config.clone();
    let path = match bundle.artifact_path("ffn_twell") {
        Ok(p) => p,
        Err(_) => return,
    };
    use repro::sparse::ffn::{forward_twell, FfnWeights};
    use repro::tensor::Mat;
    use repro::util::rng::Pcg32;
    let mut rng = Pcg32::seeded(5);
    let m = 32;
    let (k, n) = (cfg.d_model, cfg.d_ff);
    let x = Mat::randn(m, k, 1.0, &mut rng);
    let wg = Mat::randn(k, n, 0.2, &mut rng);
    let wu = Mat::randn(k, n, 0.2, &mut rng);
    let wd = Mat::randn(n, k, 0.2, &mut rng);
    let xl = lit_f32(&x.data, &[m, k]).unwrap();
    let wgl = lit_f32(&wg.data, &[k, n]).unwrap();
    let wul = lit_f32(&wu.data, &[k, n]).unwrap();
    let wdl = lit_f32(&wd.data, &[n, k]).unwrap();
    let out = rt.call(&path, &[&xl, &wgl, &wul, &wdl]).unwrap();
    let y_pallas = out[0].to_vec::<f32>().unwrap();
    // rust kernels on the same data (comp=1, lossless)
    let w = FfnWeights::new(wg, wu, wd, cfg.twell_tile_n, 1, n, 1.0);
    let (y_rust, _) = forward_twell(&w, &x);
    let mut max_err = 0f32;
    for (a, b) in y_pallas.iter().zip(&y_rust.data) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-3, "pallas vs rust max err {max_err}");
}
