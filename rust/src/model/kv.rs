//! KV-cache incremental decoding for the serving path.
//!
//! Two shapes of decode:
//!
//! * `KvCache` + `Model::decode_step` — one cache per sequence, one token
//!   per call (M=1 rows through the FFN backends).  `sample_decode` wraps
//!   it into the shared prefill+sample loop that `Model::generate` and
//!   the sequential serving path both use (`greedy_decode` is its
//!   zero-temperature wrapper, bit-exact with the historical argmax
//!   path).
//! * `PagedKvCache` + `Model::decode_step_batch` — a *paged* KV pool
//!   shared by every in-flight sequence, vLLM-style: physical storage is
//!   a global array of fixed-size blocks (`block_size` positions each),
//!   and each sequence slot owns a block *table* that maps its logical
//!   positions onto physical blocks.  Blocks are allocated from a free
//!   list as tokens are actually written and returned when the sequence
//!   retires, so short and long requests share physical KV memory
//!   instead of each stranding a fixed `max_context` region.  One
//!   `prefill_decode_step` call advances every active slot by a token
//!   *span* — a multi-token prompt chunk during prefill, one sampled
//!   token during decode (`decode_step_batch` is the all-spans-length-1
//!   case) — in a single pass, so RMSNorm/QKV/RoPE/attention and —
//!   crucially — the FFN backends run over a `(sum of span lengths, d)`
//!   activation matrix.  Every kernel on the path computes output rows
//!   independently, so batched paged decode and chunked prefill are
//!   bit-exact with the sequential path (see the parity tests below).
//!
//! Admission bookkeeping: `reserve` earmarks a slot's worst-case block
//! count up front (the scheduler admits only when `available_blocks`
//! covers it), while physical blocks are still allocated lazily as
//! positions are written — `blocks_in_use` therefore tracks tokens
//! actually held, and a reserved sequence can never hit an exhausted
//! free list mid-decode.  Over-budget reservations are a `Result`, not
//! a panic: the admission scan turns them into a wait/reject decision
//! instead of killing the shard.
//!
//! # Block sharing & copy-on-write
//!
//! With `set_prefix_cache(true)`, physical blocks carry a refcount and
//! a content identity — the tokens written into them plus a *chain
//! hash* folding in every full block before them — so a newly admitted
//! prompt can attach its leading full blocks to blocks an earlier
//! sequence already wrote (`admit`) and copy at most one divergent or
//! partially-matched block into a private block (copy-on-write).
//! Invariants:
//!
//! * **Hashability**: a block enters the lookup `index` only once all
//!   `block_size` rows are written; partially-filled blocks are
//!   reachable only as CoW sources via `children`.  Every match is
//!   verified against the stored tokens, so a hash collision costs a
//!   missed share, never a wrong one.
//! * **Refcount lifecycle**: 1 on private allocation, +1 per attaching
//!   sequence, −1 at `release_slot`.  At zero the block is *retained*
//!   on the `cached` list — still indexed, still attachable — and only
//!   evicted (identity scrubbed) when the free list runs dry.  Shared
//!   blocks are never written: a sequence writes only past its
//!   attached prefix, into blocks it owns exclusively.
//! * **Budget**: `available_blocks` counts free + retained blocks
//!   minus outstanding (not-yet-allocated) reservations; `admit`
//!   charges a request only its *unshared* worst case plus any
//!   retained blocks it revives, so sharing admits strictly more
//!   sequences per pool while `ensure_block` still can never starve.
//! * **Parity**: a K/V row depends only on the token prefix and the
//!   absolute position — never on which physical block holds it — and
//!   every kernel on the decode path computes its output rows
//!   independently, so attaching (or byte-copying) rows another
//!   sequence computed yields bit-identical logits to recomputing
//!   them.  Only block *placement* changes; decoded streams with
//!   sharing on vs off are pinned identical by the serve-level tests.
//!
//! The batched path is allocation-free: a long-lived engine owns one
//! `DecodeScratch` and calls `prefill_decode_step_into`, which draws
//! every buffer — activations, the fused q|k|v projection, attention
//! accumulators, FFN intermediates, logits, per-step bookkeeping —
//! from the scratch.  `prefill_decode_step` stays as the allocating
//! wrapper for tests and one-shot callers, and is bit-exact with the
//! scratch path by construction (identical kernels, identical order).

use crate::model::sample::{Sampler, SamplingParams};
use crate::model::{FfnBackend, Model};
use crate::sparse::dense;
use crate::sparse::ffn::{forward_backend_step_into, FfnScratch};
use crate::sparse::route::RouteScratch;
use crate::tensor::Mat;
use std::collections::{HashMap, VecDeque};
use std::fmt;

pub struct KvCache {
    /// per layer: (seq_cap, d_model) keys / values, post-RoPE
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub len: usize,
    pub cap: usize,
}

impl KvCache {
    pub fn new(model: &Model, cap: usize) -> KvCache {
        let d = model.cfg.d_model;
        KvCache {
            k: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            v: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            len: 0,
            cap,
        }
    }
}

/// An admission-time reservation that does not fit the block budget.
/// Deliberately a value, not a panic: the scheduler turns it into a
/// wait/reject decision instead of killing the shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReserveError {
    /// blocks the reservation would have charged against the budget
    pub need: usize,
    /// blocks the budget had left
    pub available: usize,
}

impl fmt::Display for ReserveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "reservation of {} blocks exceeds the budget ({} available)",
            self.need, self.available
        )
    }
}

impl std::error::Error for ReserveError {}

/// Outcome of a prefix-aware admission ([`PagedKvCache::admit`]): how
/// much of the prompt the pool already held and what attaching cost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixAdmit {
    /// prompt positions already materialized in the slot's table
    /// (`len[slot]` right after admission) — chunked prefill resumes
    /// from here.  Capped at `prompt_len - 1`: the final prompt token
    /// is always recomputed so there are logits to sample.
    pub cached_positions: usize,
    /// full blocks attached by refcount, with no data movement
    pub shared_blocks: usize,
    /// K/V rows copied into a fresh private block — the copy-on-write
    /// of the first divergent or partially-matched block (0 = no copy)
    pub cow_rows: usize,
}

/// Content identity of a physical block: the tokens written into it
/// and the chain hash of everything before it.  Recorded only while
/// prefix caching is enabled; an empty `tokens` means "no identity".
#[derive(Debug, Clone, Default)]
struct BlockMeta {
    /// chain hash through the last full block *before* this one
    parent: u64,
    /// tokens written into this block so far (≤ `block_size`)
    tokens: Vec<u32>,
    /// `chain_hash(parent, tokens)` once the block filled completely
    full_hash: Option<u64>,
}

/// Seed of every slot's hash chain (an arbitrary odd constant).
const CHAIN_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

/// splitmix64 finalizer — deterministic, dependency-free mixing.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Fold a token span into a chain hash.  Collisions are harmless
/// (matches are token-verified) but made vanishingly rare so hot
/// prefixes actually hit.
fn chain_hash(h: u64, tokens: &[u32]) -> u64 {
    let mut acc = h;
    for &t in tokens {
        acc = mix64(acc ^ (t as u64 + 1));
    }
    acc
}

/// A prefix-attach plan computed against the current index: matched
/// full blocks, how many of them must be revived off the `cached`
/// list, the chain hash at the divergence point, and the best CoW
/// source (block id, matching row count) past it.
struct PrefixPlan {
    blocks: Vec<usize>,
    pins: usize,
    chain: u64,
    cow: Option<(usize, usize)>,
}

/// Paged KV storage for the continuous-batching engine: `num_blocks`
/// physical blocks of `block_size` positions each, shared by `slots`
/// sequences through per-slot block tables.  Retiring a sequence
/// returns its blocks to the free list in O(blocks).  With
/// [`set_prefix_cache`](PagedKvCache::set_prefix_cache) enabled,
/// blocks are refcounted and content-hashed so sequences sharing a
/// prompt prefix share physical blocks (see the module docs).
pub struct PagedKvCache {
    /// per layer: (num_blocks * block_size, d_model) keys / values,
    /// post-RoPE; row `b * block_size + o` is offset `o` of physical
    /// block `b`
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    /// current length of each slot's sequence
    pub len: Vec<usize>,
    pub slots: usize,
    pub block_size: usize,
    pub num_blocks: usize,
    /// per-slot block table: physical block id of each logical block
    tables: Vec<Vec<usize>>,
    /// free physical block ids (LIFO)
    free: Vec<usize>,
    /// per-slot worst-case reservation of *private* (unshared) blocks
    /// made at admission, in blocks not yet allocated + to-allocate
    reserved: Vec<usize>,
    /// Σ over slots of blocks still promised but not yet allocated
    /// (`reserved[s]` minus the slot's private allocations so far)
    committed: usize,
    /// per-block count of sequences referencing it; 0 = free or
    /// retained on `cached`
    refcount: Vec<u32>,
    /// per-block content identity (prefix caching only)
    meta: Vec<BlockMeta>,
    /// full-block chain hash → physical block.  First writer wins;
    /// matches are token-verified, so a colliding entry only ever
    /// costs a missed share
    index: HashMap<u64, usize>,
    /// chain hash → blocks whose parent is that chain (CoW candidates,
    /// including partially-filled blocks)
    children: HashMap<u64, Vec<usize>>,
    /// refcount-0 blocks with valid contents, retained for future
    /// prefix hits; evicted FIFO when the free list runs dry
    cached: VecDeque<usize>,
    /// per-slot count of leading table entries attached by refcount
    shared: Vec<usize>,
    /// per-slot chain hash through the slot's last *full* block
    chain: Vec<u64>,
    /// master switch; off = the exact historical allocator behaviour
    prefix_cache: bool,
}

impl PagedKvCache {
    pub fn new(
        model: &Model, slots: usize, num_blocks: usize, block_size: usize,
    ) -> PagedKvCache {
        assert!(slots > 0 && num_blocks > 0 && block_size > 0);
        let d = model.cfg.d_model;
        PagedKvCache {
            k: (0..model.cfg.n_layers)
                .map(|_| Mat::zeros(num_blocks * block_size, d))
                .collect(),
            v: (0..model.cfg.n_layers)
                .map(|_| Mat::zeros(num_blocks * block_size, d))
                .collect(),
            len: vec![0; slots],
            slots,
            block_size,
            num_blocks,
            tables: vec![Vec::new(); slots],
            free: (0..num_blocks).rev().collect(),
            reserved: vec![0; slots],
            committed: 0,
            refcount: vec![0; num_blocks],
            meta: vec![BlockMeta::default(); num_blocks],
            index: HashMap::new(),
            children: HashMap::new(),
            cached: VecDeque::new(),
            shared: vec![0; slots],
            chain: vec![CHAIN_SEED; slots],
            prefix_cache: false,
        }
    }

    /// Enable or disable prefix sharing.  Only valid on an idle pool
    /// (nothing allocated, nothing reserved); disabling drops every
    /// retained prefix back to the free list, restoring the exact
    /// historical allocator behaviour.
    pub fn set_prefix_cache(&mut self, on: bool) {
        assert!(self.blocks_in_use() == 0 && self.committed == 0,
                "toggle prefix caching only on an idle pool");
        self.prefix_cache = on;
        if !on {
            while let Some(b) = self.cached.pop_front() {
                self.forget_block(b);
                self.free.push(b);
            }
            self.index.clear();
            self.children.clear();
        }
    }

    /// Whether prefix sharing is on (see `set_prefix_cache`).
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix_cache
    }

    /// Blocks needed to hold `positions` KV entries.
    pub fn blocks_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.block_size)
    }

    /// Blocks not yet promised to any slot — the admission budget:
    /// free blocks plus retained (refcount-0, evictable) prefix
    /// blocks, minus reservations that have not yet turned into
    /// allocations.
    pub fn available_blocks(&self) -> usize {
        self.free.len() + self.cached.len() - self.committed
    }

    /// Physical blocks currently held by live sequences (grows with
    /// tokens actually written, not with reservations; retained
    /// refcount-0 prefix blocks do not count — they are reclaimable).
    pub fn blocks_in_use(&self) -> usize {
        self.num_blocks - self.free.len() - self.cached.len()
    }

    /// Earmark the slot's worst-case block count (admission), with no
    /// prefix sharing.  The slot must be retired/empty; an over-budget
    /// reservation is an `Err`, never a panic — the scheduler turns it
    /// into a wait/reject decision.
    pub fn reserve(
        &mut self, slot: usize, positions: usize,
    ) -> Result<(), ReserveError> {
        // chaos-suite injection point: a panic here models an
        // allocator fault inside the admission scan, with the queue
        // lock held and requests already popped (no-op unless armed)
        crate::fail_point!("kv-reserve");
        assert!(self.len[slot] == 0 && self.reserved[slot] == 0,
                "slot {slot} still holds a sequence");
        let need = self.blocks_for(positions);
        if need > self.available_blocks() {
            return Err(ReserveError {
                need,
                available: self.available_blocks(),
            });
        }
        self.reserved[slot] = need;
        self.committed += need;
        Ok(())
    }

    /// Prefix-aware admission: reserve `positions` worth of KV for
    /// `slot`, attaching any leading full blocks the pool already
    /// holds for this prompt and copy-on-writing the first divergent
    /// or partially-matched block.  Charges the budget only the
    /// *unshared* worst case (plus retained blocks revived by the
    /// attach); over budget is an `Err` with the pool untouched.  With
    /// prefix caching disabled this is exactly `reserve`.
    pub fn admit(
        &mut self, slot: usize, prompt: &[u32], positions: usize,
    ) -> Result<PrefixAdmit, ReserveError> {
        assert!(!prompt.is_empty(), "admit with an empty prompt");
        assert!(positions >= prompt.len(),
                "positions must cover the prompt");
        if !self.prefix_cache {
            self.reserve(slot, positions)?;
            return Ok(PrefixAdmit::default());
        }
        assert!(self.len[slot] == 0 && self.reserved[slot] == 0
                    && self.tables[slot].is_empty(),
                "slot {slot} still holds a sequence");
        let total = self.blocks_for(positions);
        let plan = self.plan_prefix(prompt);
        let private_need = total - plan.blocks.len();
        let charge = plan.pins + private_need;
        if charge > self.available_blocks() {
            return Err(ReserveError {
                need: charge,
                available: self.available_blocks(),
            });
        }
        // attach the matched chain by refcount — no data movement
        for &b in &plan.blocks {
            if self.refcount[b] == 0 {
                self.cached.retain(|&x| x != b);
            }
            self.refcount[b] += 1;
            self.tables[slot].push(b);
        }
        self.shared[slot] = plan.blocks.len();
        self.chain[slot] = plan.chain;
        self.len[slot] = plan.blocks.len() * self.block_size;
        self.reserved[slot] = private_need;
        self.committed += private_need;
        // copy-on-write of the divergence block: clone the matching
        // rows of the best candidate into a fresh private block, so
        // prefill resumes mid-block.  Skipped (recomputed instead) in
        // the degenerate case where the only evictable block *is* the
        // source.
        let mut cow_rows = 0;
        if let Some((src, rows)) = plan.cow {
            if let Some(dst) = self.alloc_block(Some(src)) {
                self.committed -= 1;
                self.tables[slot].push(dst);
                let bs = self.block_size;
                for m in self.k.iter_mut().chain(self.v.iter_mut()) {
                    let c = m.cols;
                    let s0 = src * bs * c;
                    let d0 = dst * bs * c;
                    m.data.copy_within(s0..s0 + rows * c, d0);
                }
                let toks = self.meta[src].tokens[..rows].to_vec();
                self.meta[dst].parent = plan.chain;
                self.meta[dst].tokens = toks;
                self.children.entry(plan.chain).or_default().push(dst);
                self.len[slot] += rows;
                cow_rows = rows;
            }
        }
        Ok(PrefixAdmit {
            cached_positions: self.len[slot],
            shared_blocks: plan.blocks.len(),
            cow_rows,
        })
    }

    /// Walk the index along this prompt's hash chain: full blocks
    /// matched within `prompt_len - 1` positions (the final token is
    /// always recomputed so there are logits to sample), then the best
    /// partial match among the divergence point's children as a CoW
    /// source.  Read-only; `admit` applies the plan.
    fn plan_prefix(&self, prompt: &[u32]) -> PrefixPlan {
        let bs = self.block_size;
        let usable = prompt.len() - 1;
        let mut chain = CHAIN_SEED;
        let mut blocks = Vec::new();
        let mut pins = 0;
        while (blocks.len() + 1) * bs <= usable {
            let lo = blocks.len() * bs;
            let span = &prompt[lo..lo + bs];
            let h = chain_hash(chain, span);
            match self.index.get(&h) {
                Some(&b)
                    if self.meta[b].parent == chain
                        && self.meta[b].tokens == span =>
                {
                    if self.refcount[b] == 0 {
                        pins += 1;
                    }
                    blocks.push(b);
                    chain = h;
                }
                _ => break,
            }
        }
        let start = blocks.len() * bs;
        let mut cow = None;
        if usable > start {
            if let Some(kids) = self.children.get(&chain) {
                // cap at bs - 1 rows so the CoW block is strictly
                // partial — it re-enters the index through the normal
                // fill path, never with a pre-made full hash
                let budget = (usable - start).min(bs - 1);
                let mut best = (0usize, 0usize);
                for &b in kids {
                    let toks = &self.meta[b].tokens;
                    let lim = budget.min(toks.len());
                    let lcp = prompt[start..start + lim]
                        .iter()
                        .zip(&toks[..lim])
                        .take_while(|&(a, b)| a == b)
                        .count();
                    if lcp > best.1 {
                        best = (b, lcp);
                    }
                }
                if best.1 > 0 {
                    cow = Some(best);
                }
            }
        }
        PrefixPlan { blocks, pins, chain, cow }
    }

    /// Retire a slot: drop one reference from each of its blocks,
    /// retaining refcount-0 blocks with valid contents for future
    /// prefix hits (or freeing them outright when sharing is off), and
    /// release the slot's remaining reservation.
    pub fn release_slot(&mut self, slot: usize) {
        let private = self.tables[slot].len() - self.shared[slot];
        debug_assert!(private <= self.reserved[slot]);
        self.committed -= self.reserved[slot] - private;
        for b in std::mem::take(&mut self.tables[slot]) {
            self.refcount[b] -= 1;
            if self.refcount[b] == 0 {
                if self.prefix_cache && !self.meta[b].tokens.is_empty() {
                    self.cached.push_back(b);
                } else {
                    self.forget_block(b);
                    self.free.push(b);
                }
            }
        }
        self.reserved[slot] = 0;
        self.shared[slot] = 0;
        self.chain[slot] = CHAIN_SEED;
        self.len[slot] = 0;
    }

    /// Make sure the block holding position `pos == len[slot]` is
    /// allocated, allocating a private block when `pos` opens a new
    /// one.  Reservation guarantees allocation cannot fail.
    fn ensure_block(&mut self, slot: usize, pos: usize) {
        if pos == self.tables[slot].len() * self.block_size {
            let private = self.tables[slot].len() - self.shared[slot];
            assert!(private < self.reserved[slot],
                    "slot {slot} grew past its reservation");
            let b = self.alloc_block(None)
                .expect("free list empty despite reservation");
            self.committed -= 1;
            self.tables[slot].push(b);
        }
    }

    /// Allocate one private block: pop the free list, else evict the
    /// oldest retained prefix block (skipping `avoid` — a CoW source
    /// must not be evicted to make room for its own copy).  `None`
    /// only when every reclaimable block is `avoid`.
    fn alloc_block(&mut self, avoid: Option<usize>) -> Option<usize> {
        let b = match self.free.pop() {
            Some(b) => b,
            None => {
                let i = (0..self.cached.len())
                    .find(|&i| Some(self.cached[i]) != avoid)?;
                let b = self.cached.remove(i).unwrap();
                self.forget_block(b);
                b
            }
        };
        debug_assert!(
            self.refcount[b] == 0 && self.meta[b].tokens.is_empty()
        );
        self.refcount[b] = 1;
        Some(b)
    }

    /// Scrub a block's content identity: clear its metadata and remove
    /// it from the index and its parent's children list.
    fn forget_block(&mut self, b: usize) {
        let meta = std::mem::take(&mut self.meta[b]);
        if let Some(h) = meta.full_hash {
            if self.index.get(&h) == Some(&b) {
                self.index.remove(&h);
            }
        }
        if let Some(kids) = self.children.get_mut(&meta.parent) {
            kids.retain(|&x| x != b);
            if kids.is_empty() {
                self.children.remove(&meta.parent);
            }
        }
    }

    /// Advance a slot past a just-written span, recording the span's
    /// tokens into its blocks' content identity and registering each
    /// block that fills completely in the lookup index (prefix caching
    /// only — with sharing off this is `len[slot] += span.len()`).
    fn advance(&mut self, slot: usize, span: &[u32]) {
        if self.prefix_cache {
            let bs = self.block_size;
            for (j, &tok) in span.iter().enumerate() {
                let pos = self.len[slot] + j;
                let b = self.tables[slot][pos / bs];
                if pos % bs == 0 {
                    // first row of a fresh private block: open its
                    // identity under the slot's current chain
                    debug_assert!(self.meta[b].tokens.is_empty(),
                                  "reopened a block holding tokens");
                    self.meta[b].parent = self.chain[slot];
                    self.children
                        .entry(self.chain[slot])
                        .or_default()
                        .push(b);
                }
                self.meta[b].tokens.push(tok);
                if pos % bs == bs - 1 {
                    let h =
                        chain_hash(self.chain[slot], &self.meta[b].tokens);
                    self.meta[b].full_hash = Some(h);
                    self.index.entry(h).or_insert(b);
                    self.chain[slot] = h;
                }
            }
        }
        self.len[slot] += span.len();
    }

    /// Total positions slot may hold: attached prefix plus private
    /// reservation.
    fn slot_capacity(&self, slot: usize) -> usize {
        (self.shared[slot] + self.reserved[slot]) * self.block_size
    }
}

/// Reusable buffers for `Model::prefill_decode_step_into` — the
/// zero-allocation decode scratch.  One per engine, sized once at the
/// scheduler's maximum step rows (`slots * prefill_chunk`); every
/// buffer is logically reshaped per call within its high-water mark,
/// so the decode hot loop performs **no heap allocation at all**:
/// activations, the fused q|k|v projection, attention accumulators,
/// FFN intermediates (dense *and* TwELL value/index/count arrays),
/// final-token rows, logits, and the per-step bookkeeping vectors all
/// live here.
pub struct DecodeScratch {
    max_rows: usize,
    /// distinct feeds (slots) per step — bounds `last`/`logits`, which
    /// hold one row per feed, not one per span token: sizing the
    /// vocab-wide logits buffer at `max_rows` would over-allocate it by
    /// a factor of the prefill chunk
    max_feeds: usize,
    /// residual stream, (rows, d)
    x: Mat,
    /// RMSNorm output, (rows, d) — reused for both per-layer norms
    normed: Mat,
    /// fused q|k|v projections, (rows, 3d)
    qkv: Mat,
    /// attention accumulator, (rows, d)
    attn: Mat,
    /// output projection, (rows, d)
    attn_out: Mat,
    /// FFN output, (rows, d)
    ffn_y: Mat,
    /// each feed's last span token, (feeds, d)
    last: Mat,
    /// next-token logits, (feeds, vocab) — what `_into` returns
    logits: Mat,
    /// FFN intermediates (dense hg/hu, TwELL pack, fused coefficients)
    ffn: FfnScratch,
    /// batch-contextual FFN routing state: policy knobs, the per-step
    /// column union, gathered weight slices, and dispatch counters.
    /// Public so the serving engine can set the policy and drain the
    /// counters; disabled by default (routing off costs nothing)
    pub route: RouteScratch,
    /// attention score scratch, reused across heads and steps
    scores: Vec<f32>,
    /// per-feed row offsets into the packed activation matrix
    offsets: Vec<usize>,
    /// per-feed start positions (cache length at entry)
    starts: Vec<usize>,
    /// flattened physical-row lists; feed i owns
    /// `rows_flat[row_bounds[i]..row_bounds[i + 1]]`
    rows_flat: Vec<usize>,
    row_bounds: Vec<usize>,
}

impl DecodeScratch {
    /// Buffers for up to `max_rows` span tokens and `max_feeds`
    /// distinct feeds per engine step (the scheduler sizes these as
    /// `slots * prefill_chunk` and `slots`).  Only the model's active
    /// FFN backend gets pre-sized intermediates.
    pub fn new(
        model: &Model, max_rows: usize, max_feeds: usize,
    ) -> DecodeScratch {
        let max_rows = max_rows.max(1);
        let max_feeds = max_feeds.max(1).min(max_rows);
        let d = model.cfg.d_model;
        let (tile_n, comp) = match model.layers.first() {
            Some(l) => (l.ffn.tile_n, l.ffn.comp),
            None => (model.cfg.twell_tile_n.max(1), 1),
        };
        DecodeScratch {
            max_rows,
            max_feeds,
            x: Mat::zeros(max_rows, d),
            normed: Mat::zeros(max_rows, d),
            qkv: Mat::zeros(max_rows, 3 * d),
            attn: Mat::zeros(max_rows, d),
            attn_out: Mat::zeros(max_rows, d),
            ffn_y: Mat::zeros(max_rows, d),
            last: Mat::zeros(max_feeds, d),
            logits: Mat::zeros(max_feeds, model.cfg.vocab_size),
            ffn: FfnScratch::new(
                max_rows,
                model.cfg.d_ff,
                tile_n,
                comp,
                model.backend == FfnBackend::Twell,
            ),
            route: RouteScratch::new(model.cfg.d_ff, d),
            scores: Vec::new(),
            offsets: Vec::new(),
            starts: Vec::new(),
            rows_flat: Vec::new(),
            row_bounds: Vec::new(),
        }
    }
}

impl Model {
    /// Feed one token; returns the next-token logits.  Position = cache
    /// length before the call.  Q/K/V come from the fused `(d, 3d)`
    /// projection — one pass over the normed activations instead of
    /// three, bit-exact with the separate matmuls by construction.
    pub fn decode_step(&self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        assert!(cache.len < cache.cap, "kv cache full");
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let pos = cache.len;
        let mut x = Mat::zeros(1, d);
        x.row_mut(0).copy_from_slice(self.embed.row(token as usize));
        let mut scores = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            let normed = super::rmsnorm(&x, &layer.ln_attn,
                                        self.cfg.rmsnorm_eps);
            let mut qkv = dense::matmul(&normed, &layer.wqkv);
            {
                let row = qkv.row_mut(0);
                let (q, kv) = row.split_at_mut(d);
                let (k, v) = kv.split_at_mut(d);
                super::rope_row(q, pos, h, dh, &self.rope_inv_freq);
                super::rope_row(k, pos, h, dh, &self.rope_inv_freq);
                cache.k[li].row_mut(pos).copy_from_slice(k);
                cache.v[li].row_mut(pos).copy_from_slice(v);
            }
            let mut attn = Mat::zeros(1, d);
            attend_one(&qkv.row(0)[..d], &cache.k[li], &cache.v[li],
                       |t| t, pos, h, dh, attn.row_mut(0), &mut scores);
            let attn_out = dense::matmul(&attn, &layer.wo);
            super::add_inplace(&mut x, &attn_out);
            let normed = super::rmsnorm(&x, &layer.ln_ffn,
                                        self.cfg.rmsnorm_eps);
            let y = self.ffn_no_stats(layer, &normed);
            super::add_inplace(&mut x, &y);
        }
        cache.len += 1;
        let x = super::rmsnorm(&x, &self.ln_final, self.cfg.rmsnorm_eps);
        let logits = dense::matmul_nt(&x, &self.embed);
        logits.data
    }

    /// Advance every active slot by one token in a single batched pass
    /// — the all-spans-length-1 case of `prefill_decode_step`.
    ///
    /// `active` holds `(slot, token)` pairs — distinct slots, each fed at
    /// its *own* position (`cache.len[slot]`).  Returns the next-token
    /// logits as a `(B_active, vocab)` matrix in the same order.
    pub fn decode_step_batch(
        &self, cache: &mut PagedKvCache, active: &[(usize, u32)],
    ) -> Mat {
        let toks: Vec<[u32; 1]> = active.iter().map(|&(_, t)| [t]).collect();
        let feeds: Vec<(usize, &[u32])> = active
            .iter()
            .zip(&toks)
            .map(|(&(slot, _), tok)| (slot, &tok[..]))
            .collect();
        self.prefill_decode_step(cache, &feeds)
    }

    /// Allocating wrapper over `prefill_decode_step_into` for callers
    /// without a long-lived engine (tests, one-shot tools): builds a
    /// right-sized `DecodeScratch` per call and clones the logits out.
    /// The serving engine holds its own scratch and calls `_into`.
    pub fn prefill_decode_step(
        &self, cache: &mut PagedKvCache, feeds: &[(usize, &[u32])],
    ) -> Mat {
        let total: usize = feeds.iter().map(|&(_, s)| s.len()).sum();
        let mut scratch =
            DecodeScratch::new(self, total.max(1), feeds.len().max(1));
        self.prefill_decode_step_into(cache, feeds, &mut scratch).clone()
    }

    /// One engine iteration over per-slot token *spans*: each `(slot,
    /// span)` entry feeds `span.len()` consecutive tokens starting at
    /// the slot's current position — a prompt chunk during prefill, a
    /// single sampled token during decode.  Returns one logits row per
    /// entry: the next-token logits after that entry's *last* span
    /// token, in feed order (borrowed from the scratch, where they
    /// were computed — the decode hot loop never allocates).
    ///
    /// Attention is causal within the chunk: span token `j` (logical
    /// position `start + j`) attends over all cached history plus span
    /// tokens `0..=j`, whose K/V rows are written — whole blocks at a
    /// time for block-sized chunks — into paged storage before the
    /// layer's attention loop reads them back.  Every kernel on the
    /// path computes its output rows independently, so chunked prefill
    /// is bit-exact with feeding the same tokens one step at a time
    /// (the parity tests below are the contract).  Q/K/V come from one
    /// fused matmul against the layer's pre-concatenated `(d, 3d)`
    /// weight; the dense and TwELL FFN backends see the full `(sum of
    /// span lengths, d)` activation matrix, and at decode batch sizes
    /// every projection dispatches onto the column-parallel skinny
    /// kernels instead of a single core.
    pub fn prefill_decode_step_into<'s>(
        &self, cache: &mut PagedKvCache, feeds: &[(usize, &[u32])],
        scratch: &'s mut DecodeScratch,
    ) -> &'s Mat {
        assert!(!feeds.is_empty(), "prefill_decode_step with no feeds");
        for (i, &(slot, span)) in feeds.iter().enumerate() {
            assert!(slot < cache.slots, "slot {slot} out of range");
            assert!(!span.is_empty(), "slot {slot} fed an empty span");
            assert!(cache.len[slot] + span.len()
                        <= cache.slot_capacity(slot),
                    "slot {slot} kv full (reserve before decoding)");
            for &(other, _) in &feeds[i + 1..] {
                assert_ne!(slot, other, "duplicate slot in feed set");
            }
        }
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let DecodeScratch {
            max_rows,
            max_feeds,
            x,
            normed,
            qkv,
            attn,
            attn_out,
            ffn_y,
            last,
            logits,
            ffn,
            route,
            scores,
            offsets,
            starts,
            rows_flat,
            row_bounds,
        } = scratch;
        // per entry: the slot's start position, its row offset into the
        // packed (sum of span lengths, d) activation matrix, and the
        // physical row of every logical position it can attend to
        // (history + its own span).  Block tables are resolved once per
        // step — the span's blocks are allocated here, covered by the
        // slot's reservation — and shared by every layer and head, so
        // the attention loop below does plain indexed loads instead of
        // per-access div/mod table walks.
        offsets.clear();
        starts.clear();
        rows_flat.clear();
        row_bounds.clear();
        let mut total = 0usize;
        for &(_, span) in feeds {
            offsets.push(total);
            total += span.len();
        }
        assert!(
            total <= *max_rows,
            "step of {total} rows exceeds the scratch capacity {max_rows} \
             (size DecodeScratch for slots * prefill_chunk)"
        );
        assert!(
            feeds.len() <= *max_feeds,
            "step of {} feeds exceeds the scratch capacity {max_feeds} \
             (size DecodeScratch for the slot count)",
            feeds.len()
        );
        row_bounds.push(0);
        for &(slot, span) in feeds {
            let start = cache.len[slot];
            starts.push(start);
            for pos in start..start + span.len() {
                cache.ensure_block(slot, pos);
            }
            let bs = cache.block_size;
            let table = &cache.tables[slot];
            rows_flat.extend(
                (0..start + span.len()).map(|t| table[t / bs] * bs + t % bs),
            );
            row_bounds.push(rows_flat.len());
        }
        x.set_rows(total);
        for (&(_, span), &off) in feeds.iter().zip(offsets.iter()) {
            for (j, &tok) in span.iter().enumerate() {
                x.row_mut(off + j)
                    .copy_from_slice(self.embed.row(tok as usize));
            }
        }
        normed.set_rows(total);
        qkv.set_rows(total);
        attn.set_rows(total);
        attn_out.set_rows(total);
        ffn_y.set_rows(total);
        let twell = self.backend == FfnBackend::Twell;
        // batch-contextual routing applies only to pure-decode steps:
        // a ragged prefill span unions whole prompt chunks into the
        // gate and densifies the column union (see sparse::route)
        route.decode_step = feeds.iter().all(|&(_, span)| span.len() == 1);
        for (li, layer) in self.layers.iter().enumerate() {
            super::rmsnorm_into(x, &layer.ln_attn, self.cfg.rmsnorm_eps,
                                normed);
            // fused q|k|v: one (total, d) @ (d, 3d) skinny matmul
            dense::matmul_into(normed, &layer.wqkv, qkv);
            // RoPE + paged K/V writes for every span token, before the
            // attention loop reads any of them back
            for (i, &(_, span)) in feeds.iter().enumerate() {
                let rows = &rows_flat[row_bounds[i]..row_bounds[i + 1]];
                for j in 0..span.len() {
                    let r = offsets[i] + j;
                    let pos = starts[i] + j;
                    let row = qkv.row_mut(r);
                    let (q, kv) = row.split_at_mut(d);
                    let (k, v) = kv.split_at_mut(d);
                    super::rope_row(q, pos, h, dh, &self.rope_inv_freq);
                    super::rope_row(k, pos, h, dh, &self.rope_inv_freq);
                    let prow = rows[pos];
                    cache.k[li].row_mut(prow).copy_from_slice(k);
                    cache.v[li].row_mut(prow).copy_from_slice(v);
                }
            }
            attn.data.fill(0.0);
            for (i, &(_, span)) in feeds.iter().enumerate() {
                let rows = &rows_flat[row_bounds[i]..row_bounds[i + 1]];
                for j in 0..span.len() {
                    let r = offsets[i] + j;
                    // causal: history plus span tokens 0..=j
                    attend_one(&qkv.row(r)[..d], &cache.k[li],
                               &cache.v[li], |t| rows[t], starts[i] + j,
                               h, dh, attn.row_mut(r), scores);
                }
            }
            dense::matmul_into(attn, &layer.wo, attn_out);
            super::add_inplace(x, attn_out);
            super::rmsnorm_into(x, &layer.ln_ffn, self.cfg.rmsnorm_eps,
                                normed);
            // the batched FFN: (sum of span lengths, d) rows through
            // dense or TwELL via the batch-contextual router,
            // intermediates drawn from the scratch
            forward_backend_step_into(
                &layer.ffn, normed, twell, ffn, route, ffn_y,
            );
            super::add_inplace(x, ffn_y);
        }
        for &(slot, span) in feeds {
            cache.advance(slot, span);
        }
        // logits only for each entry's last span token — the rows the
        // scheduler samples from; row independence makes selecting
        // before the final norm identical to norming everything first
        last.set_rows(feeds.len());
        for (i, &(_, span)) in feeds.iter().enumerate() {
            last.row_mut(i)
                .copy_from_slice(x.row(offsets[i] + span.len() - 1));
        }
        super::rmsnorm_inplace(last, &self.ln_final, self.cfg.rmsnorm_eps);
        logits.set_rows(feeds.len());
        dense::matmul_nt_into(last, &self.embed, logits);
        logits
    }

    /// Greedy decode: prefill the prompt then emit `max_new` tokens.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        greedy_decode(self, prompt, max_new, |_, _| {})
    }
}

/// KV positions a greedy request occupies: the prompt plus every
/// generated token except the last (its logits are never needed).  The
/// single source of truth for cache sizing and scheduler admission —
/// don't re-derive this bound anywhere else.
pub fn kv_positions_needed(prompt_len: usize, max_new: usize) -> usize {
    prompt_len + max_new.saturating_sub(1)
}

/// Causal single-query attention against cached K/V positions
/// `0 .. pos` (history) plus `pos` (current, already written), with
/// `row_of` mapping a logical position to its physical storage row —
/// the identity for the contiguous `KvCache`, a block-table walk for
/// `PagedKvCache`.  The one attention inner loop both decode shapes
/// share.  `scores` is caller-owned scratch, resized here and reused
/// across heads (and across calls): this is the hottest loop in
/// decode, and it used to heap-allocate a fresh Vec per head per step.
fn attend_one(
    q: &[f32], kcache: &Mat, vcache: &Mat,
    row_of: impl Fn(usize) -> usize, pos: usize, heads: usize, dh: usize,
    out: &mut [f32], scores: &mut Vec<f32>,
) {
    let scale = 1.0 / (dh as f32).sqrt();
    scores.clear();
    scores.resize(pos + 1, 0.0);
    for head in 0..heads {
        let qh = &q[head * dh..(head + 1) * dh];
        let mut maxv = f32::NEG_INFINITY;
        for (t, s) in scores.iter_mut().enumerate() {
            let kh = &kcache.row(row_of(t))[head * dh..(head + 1) * dh];
            let sc = dense::dot(qh, kh) * scale;
            *s = sc;
            maxv = maxv.max(sc);
        }
        let mut z = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - maxv).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        let oh = &mut out[head * dh..(head + 1) * dh];
        for (t, &w) in scores.iter().enumerate() {
            let vh = &vcache.row(row_of(t))[head * dh..(head + 1) * dh];
            for (o, &vv) in oh.iter_mut().zip(vh) {
                *o += w * inv * vv;
            }
        }
    }
}

/// The shared prefill + decode loop (used by `Model::generate` and the
/// sequential serving path): feed the prompt, then draw `max_new`
/// tokens through a per-request `Sampler` (temperature / top-k /
/// top-p, seeded RNG; `temperature == 0` is exactly the old argmax
/// loop), calling `on_token(index, token)` as each one is chosen — the
/// per-token streaming hook.  The final sampled token is not fed back
/// (its logits are never needed), which keeps the KV requirement at
/// `kv_positions_needed` positions.  An empty prompt yields an empty
/// result: no token was ever fed, so there are no logits to sample.
pub fn sample_decode(
    model: &Model, prompt: &[u32], max_new: usize,
    params: SamplingParams, mut on_token: impl FnMut(usize, u32),
) -> Vec<u32> {
    if prompt.is_empty() || max_new == 0 {
        return Vec::new();
    }
    let cap = kv_positions_needed(prompt.len(), max_new);
    let mut cache = KvCache::new(model, cap);
    let mut sampler = Sampler::new(params);
    let mut logits = Vec::new();
    for &t in prompt {
        logits = model.decode_step(&mut cache, t);
    }
    let mut out = Vec::with_capacity(max_new);
    for i in 0..max_new {
        let next = sampler.sample(&logits) as u32;
        out.push(next);
        on_token(i, next);
        if i + 1 < max_new {
            logits = model.decode_step(&mut cache, next);
        }
    }
    out
}

/// The zero-temperature wrapper over `sample_decode`: bit-exact argmax
/// decoding, kept as its own entry point so every greedy parity test
/// (and `Model::generate`) pins the historical behaviour.
pub fn greedy_decode(
    model: &Model, prompt: &[u32], max_new: usize,
    on_token: impl FnMut(usize, u32),
) -> Vec<u32> {
    sample_decode(model, prompt, max_new, SamplingParams::greedy(),
                  on_token)
}

/// Index of the largest element — ties break to the **lowest index**.
/// This tie rule is load-bearing: the sampler's `temperature == 0`
/// short-circuit (`sample::Sampler::sample`) and `top_k_candidates`'s
/// equal-logit ordering both rely on it, so greedy serving stays
/// bit-exact with `Model::generate` no matter which path picked the
/// token.  Panics on empty input: an empty logits slice means no token
/// was ever fed, and silently answering "token 0" fabricates output.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty(), "argmax over empty logits");
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;
    use crate::model::FfnBackend;

    #[test]
    fn decode_matches_full_forward() {
        // incremental decoding must reproduce the batched forward logits
        let m = toy_model(FfnBackend::Dense);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 30, 7];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 16);
        let mut last = Vec::new();
        for (s, &t) in tokens.iter().enumerate() {
            last = m.decode_step(&mut cache, t);
            for (a, b) in last.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-4,
                        "mismatch at position {s}: {a} vs {b}");
            }
        }
        assert_eq!(last.len(), m.cfg.vocab_size);
    }

    #[test]
    fn decode_matches_with_twell_backend() {
        let m = toy_model(FfnBackend::Twell);
        let tokens: Vec<u32> = vec![3, 3, 8, 21];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 8);
        for (s, &t) in tokens.iter().enumerate() {
            let logits = m.decode_step(&mut cache, t);
            for (a, b) in logits.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let m = toy_model(FfnBackend::Dense);
        let a = m.generate(&[1, 2, 3], 5);
        let b = m.generate(&[1, 2, 3], 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|&t| (t as usize) < m.cfg.vocab_size));
    }

    #[test]
    fn generate_with_empty_prompt_emits_nothing() {
        // no token fed => no logits => nothing to sample (the old code
        // answered with a fabricated argmax-of-nothing token 0)
        let m = toy_model(FfnBackend::Dense);
        assert!(m.generate(&[], 5).is_empty());
        assert!(greedy_decode(&m, &[], 3, |_, _| panic!("streamed a \
            token for an empty prompt")).is_empty());
    }

    #[test]
    fn greedy_decode_streams_every_token_in_order() {
        let m = toy_model(FfnBackend::Dense);
        let mut streamed = Vec::new();
        let out = greedy_decode(&m, &[4, 4, 1], 6, |i, t| {
            assert_eq!(i, streamed.len());
            streamed.push(t);
        });
        assert_eq!(out, streamed);
        assert_eq!(out, m.generate(&[4, 4, 1], 6));
    }

    #[test]
    fn kv_positions_needed_is_the_exact_bound() {
        // prompt + max_new - 1: the last sampled token is never fed
        assert_eq!(kv_positions_needed(3, 4), 6);
        assert_eq!(kv_positions_needed(5, 1), 5);
        assert_eq!(kv_positions_needed(2, 0), 2);
        assert_eq!(kv_positions_needed(0, 0), 0);
    }

    /// Drive ragged sequences through one PagedKvCache with a block
    /// size smaller than the sequences (so attention genuinely walks
    /// multi-block tables) and check every step's logits are
    /// *bit-exact* with per-sequence `decode_step`.
    fn batch_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let seqs: [&[u32]; 3] =
            [&[1, 5, 9, 2, 30], &[7, 7], &[0, 12, 3, 3]];
        // references: independent single-sequence caches
        let mut refs: Vec<(KvCache, usize)> =
            seqs.iter().map(|_| (KvCache::new(&m, 8), 0)).collect();
        let mut batch = PagedKvCache::new(&m, 3, 16, 2);
        for (slot, s) in seqs.iter().enumerate() {
            batch.reserve(slot, s.len()).unwrap();
        }
        // step until every sequence is exhausted; shorter ones drop out,
        // making the active set genuinely ragged
        for step in 0.. {
            let active: Vec<(usize, u32)> = seqs
                .iter()
                .enumerate()
                .filter(|(_, s)| step < s.len())
                .map(|(i, s)| (i, s[step]))
                .collect();
            if active.is_empty() {
                break;
            }
            let logits = m.decode_step_batch(&mut batch, &active);
            assert_eq!(logits.rows, active.len());
            for (row, &(slot, tok)) in active.iter().enumerate() {
                let (cache, fed) = &mut refs[slot];
                let single = m.decode_step(cache, tok);
                *fed += 1;
                assert_eq!(single.as_slice(), logits.row(row),
                           "slot {slot} step {step} not bit-exact");
            }
        }
        for (slot, (_, fed)) in refs.iter().enumerate() {
            assert_eq!(*fed, seqs[slot].len());
            assert_eq!(batch.len[slot], seqs[slot].len());
        }
    }

    #[test]
    fn batched_decode_bit_exact_dense() {
        batch_parity(FfnBackend::Dense);
    }

    #[test]
    fn batched_decode_bit_exact_twell() {
        batch_parity(FfnBackend::Twell);
    }

    /// Chunked prefill must be *bit-exact* with feeding the same prompt
    /// token-by-token, for every chunk size — including chunks that
    /// straddle block boundaries and chunks larger than the prompt.
    fn chunked_prefill_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let prompt: Vec<u32> = (0..11).map(|i| (i * 5 + 1) % 32).collect();
        // reference: token-by-token through the single-sequence cache
        let mut cache = KvCache::new(&m, 16);
        let mut expect = Vec::new();
        for &t in &prompt {
            expect = m.decode_step(&mut cache, t);
        }
        for chunk in [1usize, 2, 4, 64] {
            let mut paged = PagedKvCache::new(&m, 1, 8, 2);
            paged.reserve(0, prompt.len()).unwrap();
            let mut logits = None;
            for span in prompt.chunks(chunk) {
                logits =
                    Some(m.prefill_decode_step(&mut paged, &[(0, span)]));
            }
            let logits = logits.unwrap();
            assert_eq!(logits.rows, 1);
            assert_eq!(expect.as_slice(), logits.row(0),
                       "chunk {chunk} not bit-exact ({backend:?})");
            assert_eq!(paged.len[0], prompt.len());
            paged.release_slot(0);
            assert_eq!(paged.blocks_in_use(), 0);
        }
    }

    #[test]
    fn chunked_prefill_bit_exact_dense() {
        chunked_prefill_parity(FfnBackend::Dense);
    }

    #[test]
    fn chunked_prefill_bit_exact_twell() {
        chunked_prefill_parity(FfnBackend::Twell);
    }

    /// A ragged mixed feed — one slot prefilling multi-token chunks
    /// while another advances token-by-token in the same matrix — must
    /// leave both sequences exactly where independent single-sequence
    /// decoding leaves them.
    fn mixed_prefill_decode_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let long: Vec<u32> = (0..9).map(|i| (i * 3) % 32).collect();
        let short: Vec<u32> = vec![7, 19, 2];
        let run_ref = |toks: &[u32]| {
            let mut c = KvCache::new(&m, 16);
            let mut l = Vec::new();
            for &t in toks {
                l = m.decode_step(&mut c, t);
            }
            l
        };
        let mut paged = PagedKvCache::new(&m, 2, 16, 2);
        paged.reserve(0, long.len()).unwrap();
        paged.reserve(1, short.len()).unwrap();
        let mut logits_long = Vec::new();
        let mut logits_short = Vec::new();
        for step in 0..3 {
            let feeds: Vec<(usize, &[u32])> = vec![
                (0, &long[step * 3..step * 3 + 3]),
                (1, &short[step..step + 1]),
            ];
            let l = m.prefill_decode_step(&mut paged, &feeds);
            assert_eq!(l.rows, 2);
            logits_long = l.row(0).to_vec();
            logits_short = l.row(1).to_vec();
        }
        assert_eq!(run_ref(&long), logits_long,
                   "chunked slot diverged ({backend:?})");
        assert_eq!(run_ref(&short), logits_short,
                   "single-token slot diverged ({backend:?})");
    }

    #[test]
    fn mixed_prefill_decode_bit_exact_dense() {
        mixed_prefill_decode_parity(FfnBackend::Dense);
    }

    #[test]
    fn mixed_prefill_decode_bit_exact_twell() {
        mixed_prefill_decode_parity(FfnBackend::Twell);
    }

    /// A persistent `DecodeScratch` reused across ragged
    /// prefill+decode steps must stay bit-exact with the allocating
    /// wrapper (fresh buffers every call): stale scratch contents can
    /// never leak into a later step.
    fn persistent_scratch_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let long: Vec<u32> = (0..8).map(|i| (i * 3) % 32).collect();
        let short: Vec<u32> = vec![7, 19, 2, 4];
        let mut fresh = PagedKvCache::new(&m, 2, 16, 2);
        let mut reused = PagedKvCache::new(&m, 2, 16, 2);
        for c in [&mut fresh, &mut reused] {
            c.reserve(0, long.len()).unwrap();
            c.reserve(1, short.len()).unwrap();
        }
        // capacity 3 rows / 2 feeds: span 2 (slot 0) + span 1 (slot 1)
        let mut scratch = DecodeScratch::new(&m, 3, 2);
        for step in 0..4 {
            let feeds: Vec<(usize, &[u32])> = vec![
                (0, &long[step * 2..step * 2 + 2]),
                (1, &short[step..step + 1]),
            ];
            let a = m.prefill_decode_step(&mut fresh, &feeds);
            let b =
                m.prefill_decode_step_into(&mut reused, &feeds, &mut scratch);
            assert_eq!(a.data, b.data,
                       "step {step} diverged ({backend:?})");
        }
    }

    #[test]
    fn persistent_scratch_bit_exact_dense() {
        persistent_scratch_parity(FfnBackend::Dense);
    }

    #[test]
    fn persistent_scratch_bit_exact_twell() {
        persistent_scratch_parity(FfnBackend::Twell);
    }

    /// A model wide enough that the decode-step kernels genuinely
    /// clear the pooled-dispatch work cutoffs (toy_model is far below
    /// them, so it would never exercise the column-parallel path).
    fn wide_model(backend: FfnBackend) -> Model {
        crate::model::tests_support::sized_model(
            backend, 256, 96, 2, 4, 192, 32, 4242,
        )
    }

    /// The headline determinism contract: an engine-shaped decode run
    /// — chunked prefill, then greedy feedback through a persistent
    /// scratch — produces bit-identical logits and tokens for
    /// `REPRO_THREADS ∈ {1, 4}`, for the seed row dispatch vs the
    /// pooled column-parallel fast path, **and** for batch-contextual
    /// routing off vs forced on (`max_density = 1.0` routes every
    /// pure-decode step), on both FFN backends.  The routed sweep also
    /// asserts the routed kernel genuinely ran on the TwELL backend —
    /// a silently-dead route path would pass parity vacuously.
    fn decode_stream_bit_exact(backend: FfnBackend) {
        let _g = crate::sparse::par::test_guard();
        let orig = crate::sparse::par::num_threads();
        let m = wide_model(backend);
        let prompt: Vec<u32> =
            (0..6).map(|i| ((i * 37 + 11) % 256) as u32).collect();
        let mut runs: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
        let mut configs = Vec::new();
        for &threads in &[1usize, 4] {
            for &fast in &[false, true] {
                for &routed in &[false, true] {
                    configs.push((threads, fast, routed));
                }
            }
        }
        for &(threads, fast, routed) in &configs {
            {
                crate::sparse::par::set_threads(threads);
                crate::sparse::par::set_skinny_fast_path(fast);
                let mut cache = PagedKvCache::new(&m, 3, 32, 4);
                for s in 0..3 {
                    cache.reserve(s, prompt.len() + 8).unwrap();
                }
                let mut scratch =
                    DecodeScratch::new(&m, 3 * prompt.len(), 3);
                scratch.route.enabled = routed;
                scratch.route.max_density = 1.0;
                let mut stream = Vec::new();
                let mut logit_bits = Vec::new();
                // whole-prompt prefill for all three slots in one step
                let mut toks: Vec<(usize, [u32; 1])> = {
                    let feeds: Vec<(usize, &[u32])> =
                        (0..3).map(|s| (s, &prompt[..])).collect();
                    let l = m.prefill_decode_step_into(
                        &mut cache, &feeds, &mut scratch,
                    );
                    logit_bits
                        .extend(l.row(0).iter().map(|v| v.to_bits()));
                    (0..3).map(|s| (s, [argmax(l.row(s)) as u32])).collect()
                };
                for _ in 0..8 {
                    let next: Vec<u32> = {
                        let feeds: Vec<(usize, &[u32])> = toks
                            .iter()
                            .map(|(s, t)| (*s, &t[..]))
                            .collect();
                        let l = m.prefill_decode_step_into(
                            &mut cache, &feeds, &mut scratch,
                        );
                        logit_bits
                            .extend(l.row(0).iter().map(|v| v.to_bits()));
                        (0..l.rows)
                            .map(|r| argmax(l.row(r)) as u32)
                            .collect()
                    };
                    for ((_, t), &n) in toks.iter_mut().zip(&next) {
                        t[0] = n;
                    }
                    stream.extend(next);
                }
                // routing must actually engage when forced (TwELL
                // pure-decode steps), and stay off otherwise
                let stats = scratch.route.stats.take();
                if backend == FfnBackend::Twell && routed {
                    assert!(stats.routed > 0, "routing never engaged");
                } else {
                    assert_eq!(stats.routed, 0, "routing ran unexpectedly");
                }
                runs.push((stream, logit_bits));
            }
        }
        crate::sparse::par::set_threads(orig);
        crate::sparse::par::set_skinny_fast_path(true);
        for (i, (stream, bits)) in runs[1..].iter().enumerate() {
            assert_eq!(stream, &runs[0].0,
                       "token stream diverged in run {} ({backend:?})",
                       i + 1);
            assert_eq!(bits, &runs[0].1,
                       "logits not bit-exact in run {} ({backend:?})",
                       i + 1);
        }
    }

    #[test]
    fn decode_stream_bit_exact_across_threads_and_dispatch_dense() {
        decode_stream_bit_exact(FfnBackend::Dense);
    }

    #[test]
    fn decode_stream_bit_exact_across_threads_and_dispatch_twell() {
        decode_stream_bit_exact(FfnBackend::Twell);
    }

    /// Routing boundary: a feed containing a ragged prefill span must
    /// take the fused fallback (prefill rows densify the union), while
    /// the next pure-decode step over the same scratch routes.
    #[test]
    fn mixed_feed_falls_back_while_pure_decode_routes() {
        let m = toy_model(FfnBackend::Twell);
        let n_layers = m.cfg.n_layers as u64;
        let mut cache = PagedKvCache::new(&m, 2, 16, 2);
        cache.reserve(0, 8).unwrap();
        cache.reserve(1, 8).unwrap();
        let mut scratch = DecodeScratch::new(&m, 8, 2);
        scratch.route.enabled = true;
        scratch.route.max_density = 1.0; // any union would route
        // mixed: slot 0 prefills a 3-token chunk, slot 1 is
        // decode-shaped — the whole step must fall back, without even
        // measuring a union density
        let feeds: Vec<(usize, &[u32])> =
            vec![(0, &[1, 2, 3][..]), (1, &[7][..])];
        m.prefill_decode_step_into(&mut cache, &feeds, &mut scratch);
        let s = scratch.route.stats.take();
        assert_eq!((s.routed, s.fallback), (0, n_layers));
        assert_eq!(s.density_calls, 0);
        // pure decode: every span is a single token => every layer
        // routes (and measures a density)
        let feeds: Vec<(usize, &[u32])> =
            vec![(0, &[4][..]), (1, &[9][..])];
        m.prefill_decode_step_into(&mut cache, &feeds, &mut scratch);
        let s = scratch.route.stats.take();
        assert_eq!((s.routed, s.fallback), (n_layers, 0));
        assert_eq!(s.density_calls, n_layers);
    }

    #[test]
    fn slot_release_reuses_blocks_cleanly() {
        // decode A in slot 0, retire it, decode B in the same slot: B
        // must match a fresh single-sequence cache exactly even though
        // it recycles A's physical blocks
        let m = toy_model(FfnBackend::Dense);
        let mut batch = PagedKvCache::new(&m, 2, 8, 2);
        batch.reserve(0, 4).unwrap();
        for &t in &[9u32, 2, 2, 17] {
            m.decode_step_batch(&mut batch, &[(0, t)]);
        }
        batch.release_slot(0);
        assert_eq!(batch.len[0], 0);
        assert_eq!(batch.blocks_in_use(), 0);
        batch.reserve(0, 3).unwrap();
        let mut cache = KvCache::new(&m, 8);
        for &t in &[5u32, 31, 0] {
            let lb = m.decode_step_batch(&mut batch, &[(0, t)]);
            let ls = m.decode_step(&mut cache, t);
            assert_eq!(ls.as_slice(), lb.row(0));
        }
    }

    #[test]
    fn paged_blocks_track_actual_tokens_not_capacity() {
        // the acceptance criterion: physical blocks in use grow with
        // tokens actually held — not with the reservation, and nothing
        // like slots * max_context
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 4, 32, 4);
        assert_eq!(cache.blocks_in_use(), 0);
        cache.reserve(0, 16).unwrap(); // worst case: 4 blocks promised
        assert_eq!(cache.blocks_in_use(), 0); // ...but none allocated yet
        for (n, &t) in [9u32, 2, 2, 17, 5].iter().enumerate() {
            m.decode_step_batch(&mut cache, &[(0, t)]);
            assert_eq!(cache.blocks_in_use(), (n + 1).div_ceil(4));
        }
        // 5 tokens held -> 2 blocks, despite the 4-block reservation
        assert_eq!(cache.blocks_in_use(), 2);
        cache.release_slot(0);
        assert_eq!(cache.blocks_in_use(), 0);
        assert_eq!(cache.available_blocks(), 32);
    }

    #[test]
    fn reservations_bound_the_admission_budget() {
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 8, 4);
        assert_eq!(cache.available_blocks(), 8);
        assert_eq!(cache.blocks_for(10), 3);
        cache.reserve(0, 10).unwrap(); // 3 blocks
        assert_eq!(cache.available_blocks(), 5);
        cache.reserve(1, 20).unwrap(); // 5 blocks
        assert_eq!(cache.available_blocks(), 0);
        cache.release_slot(0);
        assert_eq!(cache.available_blocks(), 3);
        cache.release_slot(1);
        assert_eq!(cache.available_blocks(), 8);
    }

    #[test]
    fn over_budget_reservation_is_rejected_not_a_panic() {
        // the admission path must get a value back, not a dead shard
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 8, 4);
        let err = cache.reserve(0, 40).unwrap_err();
        assert_eq!(err, ReserveError { need: 10, available: 8 });
        assert!(err.to_string().contains("exceeds the budget"));
        // a failed reservation leaves the pool untouched and usable
        assert_eq!(cache.available_blocks(), 8);
        cache.reserve(0, 32).unwrap();
        assert_eq!(cache.available_blocks(), 0);
        // admit() surfaces the same error with sharing enabled
        let mut shared = PagedKvCache::new(&m, 2, 8, 4);
        shared.set_prefix_cache(true);
        assert!(shared.admit(0, &[1, 2, 3], 40).is_err());
        assert_eq!(shared.available_blocks(), 8);
        assert!(shared.admit(0, &[1, 2, 3], 3).is_ok());
    }

    /// Warm-admit `prompt` into `slot` of a prefix-enabled cache and
    /// check the resulting final-token logits are bit-exact with an
    /// isolated no-sharing prefill of the same prompt.
    fn assert_warm_parity(
        m: &Model, cache: &mut PagedKvCache, slot: usize, prompt: &[u32],
        info: PrefixAdmit,
    ) {
        let l =
            m.prefill_decode_step(cache, &[(slot,
                &prompt[info.cached_positions..])]);
        let mut fresh = PagedKvCache::new(m, 1, 32, cache.block_size);
        fresh.reserve(0, prompt.len()).unwrap();
        let lf = m.prefill_decode_step(&mut fresh, &[(0, prompt)]);
        assert_eq!(lf.row(0), l.row(0),
                   "shared-prefix logits not bit-exact");
    }

    /// Two prompts sharing a multi-block prefix: the second admission
    /// attaches the full blocks, CoW-copies the divergence block, and
    /// stays bit-exact with an unshared prefill — on both backends.
    fn prefix_sharing_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let mut cache = PagedKvCache::new(&m, 3, 32, 4);
        cache.set_prefix_cache(true);
        let prefix: Vec<u32> = (0..12).map(|i| (i * 7 + 3) % 32).collect();
        let mut a = prefix.clone();
        a.extend([5, 9]);
        let mut b = prefix.clone();
        b.extend([5, 11, 2]);
        // cold: slot 0 computes everything itself
        let info = cache.admit(0, &a, a.len()).unwrap();
        assert_eq!(info, PrefixAdmit::default());
        m.prefill_decode_step(&mut cache, &[(0, &a[..])]);
        let cold_blocks = cache.blocks_in_use();
        // warm: slot 1 attaches the 3 full prefix blocks and copies
        // the 1 matching row (token 5) of the divergence block
        let info = cache.admit(1, &b, b.len()).unwrap();
        assert_eq!(
            info,
            PrefixAdmit {
                cached_positions: 13,
                shared_blocks: 3,
                cow_rows: 1
            }
        );
        assert_warm_parity(&m, &mut cache, 1, &b, info);
        // sharing held the pool flat: slot 1 added one private block,
        // not a second copy of the whole prefix
        assert_eq!(cache.blocks_in_use(), cold_blocks + 1);
    }

    #[test]
    fn prefix_sharing_bit_exact_dense() {
        prefix_sharing_parity(FfnBackend::Dense);
    }

    #[test]
    fn prefix_sharing_bit_exact_twell() {
        prefix_sharing_parity(FfnBackend::Twell);
    }

    #[test]
    fn full_prefix_hit_recomputes_only_the_last_token() {
        // an identical prompt re-admitted: every position but the last
        // comes from the pool (there must be logits to sample), and
        // the logits match the cold run bit for bit
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 32, 4);
        cache.set_prefix_cache(true);
        let prompt: Vec<u32> = (0..16).map(|i| (i * 3 + 1) % 32).collect();
        cache.admit(0, &prompt, prompt.len()).unwrap();
        let la = m.prefill_decode_step(&mut cache, &[(0, &prompt[..])]);
        let la = la.row(0).to_vec();
        let info = cache.admit(1, &prompt, prompt.len()).unwrap();
        // 16 tokens = 4 blocks, but only 15 positions are reusable:
        // 3 full blocks attach, rows 12..15 CoW-copy, the last token
        // is recomputed
        assert_eq!(
            info,
            PrefixAdmit {
                cached_positions: 15,
                shared_blocks: 3,
                cow_rows: 3
            }
        );
        let lb = m.prefill_decode_step(&mut cache, &[(1, &prompt[15..])]);
        assert_eq!(la.as_slice(), lb.row(0));
    }

    #[test]
    fn divergence_on_a_block_boundary_shares_without_cow() {
        // prompts agree for exactly one block and split on the first
        // token of the next: one attached block, no copy
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 32, 4);
        cache.set_prefix_cache(true);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5, 6, 7, 8, 9];
        let b: Vec<u32> = vec![1, 2, 3, 4, 9, 9, 9, 9, 9];
        cache.admit(0, &a, a.len()).unwrap();
        m.prefill_decode_step(&mut cache, &[(0, &a[..])]);
        let info = cache.admit(1, &b, b.len()).unwrap();
        assert_eq!(
            info,
            PrefixAdmit {
                cached_positions: 4,
                shared_blocks: 1,
                cow_rows: 0
            }
        );
        assert_warm_parity(&m, &mut cache, 1, &b, info);
    }

    #[test]
    fn prefix_shorter_than_one_block_shares_no_blocks() {
        // agreement shorter than a block never attaches by refcount —
        // each sequence owns its own physical blocks (at most the
        // matching rows are copied)
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 32, 8);
        cache.set_prefix_cache(true);
        let a: Vec<u32> = vec![1, 2, 3, 50, 51];
        let b: Vec<u32> = vec![1, 2, 3, 60, 61];
        cache.admit(0, &a, a.len()).unwrap();
        m.prefill_decode_step(&mut cache, &[(0, &a[..])]);
        let info = cache.admit(1, &b, b.len()).unwrap();
        assert_eq!(info.shared_blocks, 0);
        assert_eq!(info.cow_rows, 3);
        assert_warm_parity(&m, &mut cache, 1, &b, info);
        // one private block each — nothing refcount-shared
        assert_eq!(cache.blocks_in_use(), 2);
        cache.release_slot(0);
        let c: Vec<u32> = vec![1, 2, 3, 60, 61, 7];
        let info = cache.admit(0, &c, c.len()).unwrap();
        assert_eq!((info.shared_blocks, info.cow_rows), (0, 5));
        assert_warm_parity(&m, &mut cache, 0, &c, info);
    }

    #[test]
    fn release_order_with_shared_refcounts() {
        // the donor retires FIRST; the sharer's attached blocks must
        // survive (refcount > 1 at attach time) and keep decoding
        // bit-exactly, and only the final release reclaims everything
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 2, 16, 2);
        cache.set_prefix_cache(true);
        let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2];
        cache.admit(0, &prompt, prompt.len() + 4).unwrap();
        m.prefill_decode_step(&mut cache, &[(0, &prompt[..])]);
        let info = cache.admit(1, &prompt, prompt.len() + 4).unwrap();
        assert_eq!(info.shared_blocks, 3);
        m.prefill_decode_step(
            &mut cache, &[(1, &prompt[info.cached_positions..])]);
        let held = cache.blocks_in_use();
        cache.release_slot(0);
        // shared blocks still referenced by slot 1: not reclaimable
        assert!(cache.blocks_in_use() >= info.shared_blocks);
        assert!(cache.blocks_in_use() <= held);
        // slot 1 decodes on: greedy feedback vs an isolated reference
        let mut kv = KvCache::new(&m, 16);
        let mut expect = Vec::new();
        for &t in &prompt {
            expect = m.decode_step(&mut kv, t);
        }
        let mut tok = [argmax(&expect) as u32];
        for _ in 0..3 {
            let lb =
                m.prefill_decode_step(&mut cache, &[(1, &tok[..])]);
            let ls = m.decode_step(&mut kv, tok[0]);
            assert_eq!(ls.as_slice(), lb.row(0),
                       "sharer diverged after donor release");
            tok[0] = argmax(&ls) as u32;
        }
        cache.release_slot(1);
        assert_eq!(cache.blocks_in_use(), 0);
        assert_eq!(cache.available_blocks(), 16);
    }

    #[test]
    fn retained_prefixes_are_evicted_under_pressure() {
        // a retired donor's blocks are retained for hits but evicted
        // (identity scrubbed) the moment a stranger needs the space
        let m = toy_model(FfnBackend::Dense);
        let mut cache = PagedKvCache::new(&m, 1, 4, 2);
        cache.set_prefix_cache(true);
        let a: Vec<u32> = vec![1, 2, 3, 4, 5];
        cache.admit(0, &a, a.len()).unwrap();
        m.prefill_decode_step(&mut cache, &[(0, &a[..])]);
        cache.release_slot(0);
        assert_eq!(cache.blocks_in_use(), 0);
        assert_eq!(cache.available_blocks(), 4);
        // a disjoint prompt needing the whole pool must still admit
        let b: Vec<u32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
        let info = cache.admit(0, &b, b.len()).unwrap();
        assert_eq!(info, PrefixAdmit::default());
        let lb = m.prefill_decode_step(&mut cache, &[(0, &b[..])]);
        let mut kv = KvCache::new(&m, 8);
        let mut ls = Vec::new();
        for &t in &b {
            ls = m.decode_step(&mut kv, t);
        }
        assert_eq!(ls.as_slice(), lb.row(0));
        cache.release_slot(0);
        // the evicted prefix is really gone: admitting `a` is cold
        let info = cache.admit(0, &a, a.len()).unwrap();
        assert_eq!(info.shared_blocks, 0);
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 3.0]), 1); // first max wins
    }

    #[test]
    fn argmax_ties_break_to_the_lowest_index() {
        // the documented contract the sampler's t=0 short-circuit and
        // top_k_candidates' equal-logit ordering both rely on: among
        // equal maxima, the lowest index wins — always
        assert_eq!(argmax(&[7.0, 7.0, 7.0]), 0); // all equal
        assert_eq!(argmax(&[-1.0, 2.0, 2.0, 2.0]), 1); // run of maxima
        assert_eq!(argmax(&[5.0]), 0); // singleton
        assert_eq!(argmax(&[0.0, -0.0]), 0); // 0.0 > -0.0 is false: tie
        assert_eq!(
            argmax(&[f32::NEG_INFINITY, f32::NEG_INFINITY]),
            0,
            "non-finite ties must also break low"
        );
    }

    #[test]
    fn sample_decode_is_seed_reproducible_and_t0_is_greedy() {
        let m = toy_model(FfnBackend::Dense);
        let params = SamplingParams {
            temperature: 0.9,
            top_k: 8,
            top_p: 0.9,
            seed: 321,
        };
        let a = sample_decode(&m, &[4, 4, 1], 6, params, |_, _| {});
        let b = sample_decode(&m, &[4, 4, 1], 6, params, |_, _| {});
        assert_eq!(a, b, "same seed must reproduce the stream");
        assert_eq!(a.len(), 6);
        assert!(a.iter().all(|&t| (t as usize) < m.cfg.vocab_size));
        // temperature 0: bit-exact with the greedy wrapper, whatever
        // the truncation settings say
        let z = SamplingParams {
            temperature: 0.0,
            top_k: 2,
            top_p: 0.3,
            seed: 5,
        };
        let greedy = greedy_decode(&m, &[4, 4, 1], 6, |_, _| {});
        assert_eq!(sample_decode(&m, &[4, 4, 1], 6, z, |_, _| {}), greedy);
        assert_eq!(greedy, m.generate(&[4, 4, 1], 6));
    }

    #[test]
    #[should_panic(expected = "argmax over empty logits")]
    fn argmax_rejects_empty_logits() {
        argmax(&[]);
    }
}
