//! KV-cache incremental decoding for the serving path.
//!
//! Two shapes of decode:
//!
//! * `KvCache` + `Model::decode_step` — one cache per sequence, one token
//!   per call (M=1 rows through the FFN backends).  `greedy_decode` wraps
//!   it into the shared prefill+argmax loop that `Model::generate` and
//!   the sequential serving path both use.
//! * `BatchKvCache` + `Model::decode_step_batch` — a fixed pool of KV
//!   *slots* in slot-major storage; one call advances every active slot
//!   at its own position in a single pass, so RMSNorm/QKV/RoPE/attention
//!   and — crucially — the FFN backends run over a `(B_active, d)`
//!   activation matrix.  This is what the continuous-batching server
//!   drives.  Every kernel on the path computes output rows
//!   independently, so batched decode is bit-exact with the sequential
//!   path (see the parity tests below).

use crate::model::Model;
use crate::sparse::dense;
use crate::tensor::Mat;

pub struct KvCache {
    /// per layer: (seq_cap, d_model) keys / values, post-RoPE
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub len: usize,
    pub cap: usize,
}

impl KvCache {
    pub fn new(model: &Model, cap: usize) -> KvCache {
        let d = model.cfg.d_model;
        KvCache {
            k: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            v: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            len: 0,
            cap,
        }
    }
}

/// Pooled KV storage for the continuous-batching engine: `slots`
/// independent sequences, each with `cap` positions, stored slot-major
/// (slot `s` owns rows `s*cap .. (s+1)*cap` of every layer matrix).
/// Retiring a sequence is O(1): reset the slot's length and the rows are
/// reused by the next admission.
pub struct BatchKvCache {
    /// per layer: (slots * cap, d_model) keys / values, post-RoPE
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    /// current length of each slot's sequence
    pub len: Vec<usize>,
    pub slots: usize,
    pub cap: usize,
}

impl BatchKvCache {
    pub fn new(model: &Model, slots: usize, cap: usize) -> BatchKvCache {
        assert!(slots > 0 && cap > 0);
        let d = model.cfg.d_model;
        BatchKvCache {
            k: (0..model.cfg.n_layers)
                .map(|_| Mat::zeros(slots * cap, d))
                .collect(),
            v: (0..model.cfg.n_layers)
                .map(|_| Mat::zeros(slots * cap, d))
                .collect(),
            len: vec![0; slots],
            slots,
            cap,
        }
    }

    /// Free a slot for reuse (retired sequence / new admission).
    pub fn reset_slot(&mut self, slot: usize) {
        self.len[slot] = 0;
    }
}

impl Model {
    /// Feed one token; returns the next-token logits.  Position = cache
    /// length before the call.
    pub fn decode_step(&self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        assert!(cache.len < cache.cap, "kv cache full");
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let pos = cache.len;
        let mut x = Mat::zeros(1, d);
        x.row_mut(0).copy_from_slice(self.embed.row(token as usize));
        for (li, layer) in self.layers.iter().enumerate() {
            let normed = super::rmsnorm(&x, &layer.ln_attn,
                                        self.cfg.rmsnorm_eps);
            let mut q = dense::matmul(&normed, &layer.wq);
            let mut k = dense::matmul(&normed, &layer.wk);
            let v = dense::matmul(&normed, &layer.wv);
            super::rope_row(q.row_mut(0), pos, h, dh, self.cfg.rope_theta);
            super::rope_row(k.row_mut(0), pos, h, dh, self.cfg.rope_theta);
            cache.k[li].row_mut(pos).copy_from_slice(k.row(0));
            cache.v[li].row_mut(pos).copy_from_slice(v.row(0));
            let mut attn = Mat::zeros(1, d);
            attend_one(q.row(0), &cache.k[li], &cache.v[li], 0, pos, h, dh,
                       attn.row_mut(0));
            let attn_out = dense::matmul(&attn, &layer.wo);
            super::add_inplace(&mut x, &attn_out);
            let normed = super::rmsnorm(&x, &layer.ln_ffn,
                                        self.cfg.rmsnorm_eps);
            let y = self.ffn_no_stats(layer, &normed);
            super::add_inplace(&mut x, &y);
        }
        cache.len += 1;
        let x = super::rmsnorm(&x, &self.ln_final, self.cfg.rmsnorm_eps);
        let logits = dense::matmul_nt(&x, &self.embed);
        logits.data
    }

    /// Advance every active slot by one token in a single batched pass.
    ///
    /// `active` holds `(slot, token)` pairs — distinct slots, each fed at
    /// its *own* position (`cache.len[slot]`).  Returns the next-token
    /// logits as a `(B_active, vocab)` matrix in the same order.  The
    /// dense and TwELL FFN backends both see the full `(B_active, d)`
    /// activation matrix, which is the whole point of continuous
    /// batching for the sparse pipeline.
    pub fn decode_step_batch(
        &self, cache: &mut BatchKvCache, active: &[(usize, u32)],
    ) -> Mat {
        let b = active.len();
        assert!(b > 0, "decode_step_batch with no active slots");
        for (i, &(slot, _)) in active.iter().enumerate() {
            assert!(slot < cache.slots, "slot {slot} out of range");
            assert!(cache.len[slot] < cache.cap, "slot {slot} kv full");
            for &(other, _) in &active[i + 1..] {
                assert_ne!(slot, other, "duplicate slot in active set");
            }
        }
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let mut x = Mat::zeros(b, d);
        for (i, &(_, tok)) in active.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        for (li, layer) in self.layers.iter().enumerate() {
            let normed = super::rmsnorm(&x, &layer.ln_attn,
                                        self.cfg.rmsnorm_eps);
            let mut q = dense::matmul(&normed, &layer.wq);
            let mut k = dense::matmul(&normed, &layer.wk);
            let v = dense::matmul(&normed, &layer.wv);
            for (i, &(slot, _)) in active.iter().enumerate() {
                let pos = cache.len[slot];
                super::rope_row(q.row_mut(i), pos, h, dh,
                                self.cfg.rope_theta);
                super::rope_row(k.row_mut(i), pos, h, dh,
                                self.cfg.rope_theta);
                let row = slot * cache.cap + pos;
                cache.k[li].row_mut(row).copy_from_slice(k.row(i));
                cache.v[li].row_mut(row).copy_from_slice(v.row(i));
            }
            let mut attn = Mat::zeros(b, d);
            for (i, &(slot, _)) in active.iter().enumerate() {
                let pos = cache.len[slot];
                attend_one(q.row(i), &cache.k[li], &cache.v[li],
                           slot * cache.cap, pos, h, dh, attn.row_mut(i));
            }
            let attn_out = dense::matmul(&attn, &layer.wo);
            super::add_inplace(&mut x, &attn_out);
            let normed = super::rmsnorm(&x, &layer.ln_ffn,
                                        self.cfg.rmsnorm_eps);
            // the batched FFN: (B_active, d) rows through dense or TwELL
            let y = self.ffn_no_stats(layer, &normed);
            super::add_inplace(&mut x, &y);
        }
        for &(slot, _) in active {
            cache.len[slot] += 1;
        }
        let x = super::rmsnorm(&x, &self.ln_final, self.cfg.rmsnorm_eps);
        dense::matmul_nt(&x, &self.embed)
    }

    /// Greedy decode: prefill the prompt then emit `max_new` tokens.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        greedy_decode(self, prompt, max_new, |_, _| {})
    }
}

/// Causal single-query attention against cached K/V rows
/// `base .. base+pos` (history) plus `base+pos` (current, already
/// written): the one attention inner loop both decode shapes share.
fn attend_one(
    q: &[f32], kcache: &Mat, vcache: &Mat, base: usize, pos: usize,
    heads: usize, dh: usize, out: &mut [f32],
) {
    let scale = 1.0 / (dh as f32).sqrt();
    for head in 0..heads {
        let qh = &q[head * dh..(head + 1) * dh];
        let mut scores = Vec::with_capacity(pos + 1);
        let mut maxv = f32::NEG_INFINITY;
        for t in 0..=pos {
            let kh = &kcache.row(base + t)[head * dh..(head + 1) * dh];
            let sc = dense::dot(qh, kh) * scale;
            scores.push(sc);
            maxv = maxv.max(sc);
        }
        let mut z = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - maxv).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        let oh = &mut out[head * dh..(head + 1) * dh];
        for (t, &w) in scores.iter().enumerate() {
            let vh = &vcache.row(base + t)[head * dh..(head + 1) * dh];
            for (o, &vv) in oh.iter_mut().zip(vh) {
                *o += w * inv * vv;
            }
        }
    }
}

/// The shared greedy prefill + decode loop (used by `Model::generate`
/// and the serving paths): feed the prompt, then argmax `max_new`
/// tokens, calling `on_token(index, token)` as each one is chosen — the
/// per-token streaming hook.  The final sampled token is not fed back
/// (its logits are never needed), which keeps the KV requirement at
/// `prompt.len() + max_new - 1` positions.
pub fn greedy_decode(
    model: &Model, prompt: &[u32], max_new: usize,
    mut on_token: impl FnMut(usize, u32),
) -> Vec<u32> {
    let mut cache = KvCache::new(model, (prompt.len() + max_new).max(1));
    let mut logits = Vec::new();
    for &t in prompt {
        logits = model.decode_step(&mut cache, t);
    }
    let mut out = Vec::with_capacity(max_new);
    for i in 0..max_new {
        let next = argmax(&logits) as u32;
        out.push(next);
        on_token(i, next);
        if i + 1 < max_new {
            logits = model.decode_step(&mut cache, next);
        }
    }
    out
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;
    use crate::model::FfnBackend;

    #[test]
    fn decode_matches_full_forward() {
        // incremental decoding must reproduce the batched forward logits
        let m = toy_model(FfnBackend::Dense);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 30, 7];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 16);
        let mut last = Vec::new();
        for (s, &t) in tokens.iter().enumerate() {
            last = m.decode_step(&mut cache, t);
            for (a, b) in last.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-4,
                        "mismatch at position {s}: {a} vs {b}");
            }
        }
        assert_eq!(last.len(), m.cfg.vocab_size);
    }

    #[test]
    fn decode_matches_with_twell_backend() {
        let m = toy_model(FfnBackend::Twell);
        let tokens: Vec<u32> = vec![3, 3, 8, 21];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 8);
        for (s, &t) in tokens.iter().enumerate() {
            let logits = m.decode_step(&mut cache, t);
            for (a, b) in logits.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let m = toy_model(FfnBackend::Dense);
        let a = m.generate(&[1, 2, 3], 5);
        let b = m.generate(&[1, 2, 3], 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|&t| (t as usize) < m.cfg.vocab_size));
    }

    #[test]
    fn greedy_decode_streams_every_token_in_order() {
        let m = toy_model(FfnBackend::Dense);
        let mut streamed = Vec::new();
        let out = greedy_decode(&m, &[4, 4, 1], 6, |i, t| {
            assert_eq!(i, streamed.len());
            streamed.push(t);
        });
        assert_eq!(out, streamed);
        assert_eq!(out, m.generate(&[4, 4, 1], 6));
    }

    /// Drive ragged sequences through one BatchKvCache and check every
    /// step's logits are *bit-exact* with per-sequence `decode_step`.
    fn batch_parity(backend: FfnBackend) {
        let m = toy_model(backend);
        let seqs: [&[u32]; 3] =
            [&[1, 5, 9, 2, 30], &[7, 7], &[0, 12, 3, 3]];
        // references: independent single-sequence caches
        let mut refs: Vec<(KvCache, usize)> =
            seqs.iter().map(|_| (KvCache::new(&m, 8), 0)).collect();
        let mut batch = BatchKvCache::new(&m, 3, 8);
        // step until every sequence is exhausted; shorter ones drop out,
        // making the active set genuinely ragged
        for step in 0.. {
            let active: Vec<(usize, u32)> = seqs
                .iter()
                .enumerate()
                .filter(|(_, s)| step < s.len())
                .map(|(i, s)| (i, s[step]))
                .collect();
            if active.is_empty() {
                break;
            }
            let logits = m.decode_step_batch(&mut batch, &active);
            assert_eq!(logits.rows, active.len());
            for (row, &(slot, tok)) in active.iter().enumerate() {
                let (cache, fed) = &mut refs[slot];
                let single = m.decode_step(cache, tok);
                *fed += 1;
                assert_eq!(single.as_slice(), logits.row(row),
                           "slot {slot} step {step} not bit-exact");
            }
        }
        for (slot, (_, fed)) in refs.iter().enumerate() {
            assert_eq!(*fed, seqs[slot].len());
            assert_eq!(batch.len[slot], seqs[slot].len());
        }
    }

    #[test]
    fn batched_decode_bit_exact_dense() {
        batch_parity(FfnBackend::Dense);
    }

    #[test]
    fn batched_decode_bit_exact_twell() {
        batch_parity(FfnBackend::Twell);
    }

    #[test]
    fn slot_reset_reuses_storage_cleanly() {
        // decode A in slot 0, retire it, decode B in the same slot: B
        // must match a fresh single-sequence cache exactly
        let m = toy_model(FfnBackend::Dense);
        let mut batch = BatchKvCache::new(&m, 2, 8);
        for &t in &[9u32, 2, 2, 17] {
            m.decode_step_batch(&mut batch, &[(0, t)]);
        }
        batch.reset_slot(0);
        assert_eq!(batch.len[0], 0);
        let mut cache = KvCache::new(&m, 8);
        for &t in &[5u32, 31, 0] {
            let lb = m.decode_step_batch(&mut batch, &[(0, t)]);
            let ls = m.decode_step(&mut cache, t);
            assert_eq!(ls.as_slice(), lb.row(0));
        }
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 3.0]), 1); // first max wins
    }
}
