//! KV-cache incremental decoding for the serving path.
//!
//! One cache per sequence; `Model::decode_step` runs a single token
//! through the network reusing cached keys/values, with the FFN executing
//! through the configured backend (M=1 rows exercise the same TwELL
//! pipeline the batched path uses).

use crate::model::{FfnBackend, Model};
use crate::sparse::dense;
use crate::sparse::ffn::{forward_dense, forward_twell};
use crate::tensor::Mat;

pub struct KvCache {
    /// per layer: (seq_cap, d_model) keys / values, post-RoPE
    pub k: Vec<Mat>,
    pub v: Vec<Mat>,
    pub len: usize,
    pub cap: usize,
}

impl KvCache {
    pub fn new(model: &Model, cap: usize) -> KvCache {
        let d = model.cfg.d_model;
        KvCache {
            k: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            v: (0..model.cfg.n_layers).map(|_| Mat::zeros(cap, d)).collect(),
            len: 0,
            cap,
        }
    }
}

impl Model {
    /// Feed one token; returns the next-token logits.  Position = cache
    /// length before the call.
    pub fn decode_step(&self, cache: &mut KvCache, token: u32) -> Vec<f32> {
        assert!(cache.len < cache.cap, "kv cache full");
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let pos = cache.len;
        let mut x = Mat::zeros(1, d);
        x.row_mut(0).copy_from_slice(self.embed.row(token as usize));
        for (li, layer) in self.layers.iter().enumerate() {
            let normed = super::rmsnorm(&x, &layer.ln_attn,
                                        self.cfg.rmsnorm_eps);
            let mut q = dense::matmul(&normed, &layer.wq);
            let mut k = dense::matmul(&normed, &layer.wk);
            let v = dense::matmul(&normed, &layer.wv);
            super::rope_row(q.row_mut(0), pos, h, dh, self.cfg.rope_theta);
            super::rope_row(k.row_mut(0), pos, h, dh, self.cfg.rope_theta);
            cache.k[li].row_mut(pos).copy_from_slice(k.row(0));
            cache.v[li].row_mut(pos).copy_from_slice(v.row(0));
            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn = Mat::zeros(1, d);
            for head in 0..h {
                let qh = &q.row(0)[head * dh..(head + 1) * dh];
                let mut scores = Vec::with_capacity(pos + 1);
                let mut maxv = f32::NEG_INFINITY;
                for t in 0..=pos {
                    let kh =
                        &cache.k[li].row(t)[head * dh..(head + 1) * dh];
                    let sc = dense::dot(qh, kh) * scale;
                    scores.push(sc);
                    maxv = maxv.max(sc);
                }
                let mut z = 0f32;
                for s in scores.iter_mut() {
                    *s = (*s - maxv).exp();
                    z += *s;
                }
                let inv = 1.0 / z;
                let oh = &mut attn.row_mut(0)[head * dh..(head + 1) * dh];
                for (t, &w) in scores.iter().enumerate() {
                    let vh =
                        &cache.v[li].row(t)[head * dh..(head + 1) * dh];
                    for (o, &vv) in oh.iter_mut().zip(vh) {
                        *o += w * inv * vv;
                    }
                }
            }
            let attn_out = dense::matmul(&attn, &layer.wo);
            super::add_inplace(&mut x, &attn_out);
            let normed = super::rmsnorm(&x, &layer.ln_ffn,
                                        self.cfg.rmsnorm_eps);
            let y = match self.backend {
                FfnBackend::Dense => forward_dense(&layer.ffn, &normed),
                FfnBackend::Twell => forward_twell(&layer.ffn, &normed).0,
            };
            super::add_inplace(&mut x, &y);
        }
        cache.len += 1;
        let x = super::rmsnorm(&x, &self.ln_final, self.cfg.rmsnorm_eps);
        let logits = dense::matmul_nt(&x, &self.embed);
        logits.data
    }

    /// Greedy decode: prefill the prompt then emit `max_new` tokens.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut cache = KvCache::new(self, prompt.len() + max_new + 1);
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_step(&mut cache, t);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let next = argmax(&logits) as u32;
            out.push(next);
            logits = self.decode_step(&mut cache, next);
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;

    #[test]
    fn decode_matches_full_forward() {
        // incremental decoding must reproduce the batched forward logits
        let m = toy_model(FfnBackend::Dense);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 30, 7];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 16);
        let mut last = Vec::new();
        for (s, &t) in tokens.iter().enumerate() {
            last = m.decode_step(&mut cache, t);
            for (a, b) in last.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-4,
                        "mismatch at position {s}: {a} vs {b}");
            }
        }
        assert_eq!(last.len(), m.cfg.vocab_size);
    }

    #[test]
    fn decode_matches_with_twell_backend() {
        let m = toy_model(FfnBackend::Twell);
        let tokens: Vec<u32> = vec![3, 3, 8, 21];
        let (full, _) = m.forward(&tokens, 1, tokens.len());
        let mut cache = KvCache::new(&m, 8);
        for (s, &t) in tokens.iter().enumerate() {
            let logits = m.decode_step(&mut cache, t);
            for (a, b) in logits.iter().zip(full.row(s)) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let m = toy_model(FfnBackend::Dense);
        let a = m.generate(&[1, 2, 3], 5);
        let b = m.generate(&[1, 2, 3], 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        assert!(a.iter().all(|&t| (t as usize) < m.cfg.vocab_size));
    }

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 3.0]), 1); // first max wins
    }
}
