//! Rust transformer inference engine — the serving-side counterpart of
//! the jax model (python/compile/model.py), loading coordinator
//! checkpoints and running forward passes with a *pluggable FFN backend*:
//! dense GEMMs (baseline) or the paper's two-kernel TwELL pipeline.
//!
//! Numerics mirror the jax model exactly (RMSNorm, half-split RoPE,
//! causal softmax attention, tied embeddings); the integration test
//! `forward_parity_with_pjrt` cross-validates against the AOT `forward`
//! artifact.
//!
//! Decoding comes in two shapes (`kv`): the single-sequence
//! `decode_step`, and `decode_step_batch` over a block-paged
//! `PagedKvCache`, which the continuous-batching server (`serve`)
//! drives so the FFN backends see multi-row activations during decode
//! while sequences share physical KV memory.  Token selection lives in
//! `sample`: per-request temperature / top-k / top-p with a seeded
//! RNG, where `temperature == 0` reduces to the greedy argmax path.

pub mod kv;
pub mod sample;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::coordinator::ckpt::Checkpoint;
use crate::sparse::ffn::{forward_backend, forward_dense, forward_twell,
                         FfnWeights};
use crate::sparse::{dense, par};
use crate::tensor::Mat;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FfnBackend {
    Dense,
    Twell,
}

pub struct Layer {
    pub ln_attn: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub ln_ffn: Vec<f32>,
    pub ffn: FfnWeights,
    /// `[Wq | Wk | Wv]` column-concatenated, (d, 3d), built once at
    /// load: the decode path projects Q/K/V with **one** skinny matmul
    /// over the normed activations instead of three passes.  Column
    /// concatenation keeps each projection's per-element accumulation
    /// identical to the separate matmuls, so the fused projection is
    /// bit-exact with them.  The separate `wq`/`wk`/`wv` are kept for
    /// the full-sequence forward path — a deliberate 3·d² f32/layer
    /// duplication (trivial at current scales) that leaves the
    /// prefill/eval numerics code untouched.
    pub wqkv: Mat,
}

impl Layer {
    /// Assemble a layer, deriving the fused QKV weight.
    pub fn new(
        ln_attn: Vec<f32>, wq: Mat, wk: Mat, wv: Mat, wo: Mat,
        ln_ffn: Vec<f32>, ffn: FfnWeights,
    ) -> Layer {
        let wqkv = Mat::hcat(&[&wq, &wk, &wv]);
        Layer { ln_attn, wq, wk, wv, wo, ln_ffn, ffn, wqkv }
    }
}

pub struct Model {
    pub cfg: ModelConfig,
    pub embed: Mat, // (V, d); tied: output head = embed^T
    pub layers: Vec<Layer>,
    pub ln_final: Vec<f32>,
    pub backend: FfnBackend,
    /// TwELL compression factor used by the sparse backend (comp=1 is
    /// lossless; higher values trade storage for drop risk like the
    /// paper's conservative setting).
    pub comp: usize,
    /// RoPE inverse frequencies `1 / theta^(i / (dh/2))`, precomputed
    /// once at load — `rope_row` used to recompute the `powf` per head
    /// per token per decode step.
    pub rope_inv_freq: Vec<f32>,
}

/// Per-layer sparsity observations from a forward pass (figure 6 data).
#[derive(Clone, Debug, Default)]
pub struct ForwardStats {
    /// summed gate nnz per layer over all processed tokens
    pub nnz_per_layer: Vec<u64>,
    /// wall-clock seconds spent in each layer's FFN (speedup attribution)
    pub ffn_seconds: Vec<f64>,
    pub tokens: usize,
}

impl ForwardStats {
    pub fn avg_nnz(&self, layer: usize) -> f64 {
        self.nnz_per_layer[layer] as f64 / self.tokens.max(1) as f64
    }
}

impl Model {
    /// Assemble a model from its parts, deriving the load-time caches
    /// (RoPE inverse-frequency table; each `Layer::new` has already
    /// derived its fused QKV weight).  Every construction site —
    /// checkpoint loading, tests, benches — funnels through here so
    /// the caches can never be forgotten.
    pub fn assemble(
        cfg: ModelConfig, embed: Mat, layers: Vec<Layer>,
        ln_final: Vec<f32>, backend: FfnBackend, comp: usize,
    ) -> Model {
        let rope_inv_freq = rope_inv_freq(cfg.head_dim(), cfg.rope_theta);
        Model { cfg, embed, layers, ln_final, backend, comp, rope_inv_freq }
    }

    pub fn from_checkpoint(ck: &Checkpoint, backend: FfnBackend)
        -> Result<Model> {
        let cfg = ck.config.clone();
        if !cfg.gated {
            bail!("rust engine currently loads gated checkpoints only");
        }
        let getm = |name: &str| -> Result<Mat> {
            let (shape, data) = ck.get(name)?;
            anyhow::ensure!(shape.len() == 2, "{name} not 2d");
            Ok(Mat::from_vec(shape[0], shape[1], data.to_vec()))
        };
        let getv = |name: &str| -> Result<Vec<f32>> {
            Ok(ck.get(name)?.1.to_vec())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let p = format!("layer{l}.");
            let ffn = FfnWeights::new(
                getm(&format!("{p}wg"))?,
                getm(&format!("{p}wu"))?,
                getm(&format!("{p}wd"))?,
                cfg.twell_tile_n,
                1, // lossless compression for exact parity; benches vary it
                cfg.ell_width,
                cfg.dense_backup_frac,
            );
            layers.push(Layer::new(
                getv(&format!("{p}ln_attn"))?,
                getm(&format!("{p}wq"))?,
                getm(&format!("{p}wk"))?,
                getm(&format!("{p}wv"))?,
                getm(&format!("{p}wo"))?,
                getv(&format!("{p}ln_ffn"))?,
                ffn,
            ));
        }
        let embed = getm("embed")?;
        let ln_final = getv("ln_final")?;
        Ok(Model::assemble(cfg, embed, layers, ln_final, backend, 1))
    }

    /// Full-sequence forward for a batch of equal-length sequences.
    /// Returns logits (B*S, V) row-major and per-layer sparsity stats.
    pub fn forward(&self, tokens: &[u32], batch: usize, seq: usize)
        -> (Mat, ForwardStats) {
        assert_eq!(tokens.len(), batch * seq);
        let d = self.cfg.d_model;
        let mut x = Mat::zeros(batch * seq, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(t as usize));
        }
        let mut stats = ForwardStats {
            nnz_per_layer: vec![0; self.layers.len()],
            ffn_seconds: vec![0.0; self.layers.len()],
            tokens: batch * seq,
        };
        for (li, layer) in self.layers.iter().enumerate() {
            let normed = rmsnorm(&x, &layer.ln_attn, self.cfg.rmsnorm_eps);
            let attn = self.attention(layer, &normed, batch, seq);
            add_inplace(&mut x, &attn);
            let normed = rmsnorm(&x, &layer.ln_ffn, self.cfg.rmsnorm_eps);
            let ffn_t0 = std::time::Instant::now();
            let y = match self.backend {
                FfnBackend::Dense => {
                    // count nnz on the dense gate for stats parity
                    let hg = dense::matmul_relu(&normed, &layer.ffn.wg);
                    stats.nnz_per_layer[li] += hg.nnz_positive() as u64;
                    forward_dense(&layer.ffn, &normed)
                }
                FfnBackend::Twell => {
                    let (y, hg) = forward_twell(&layer.ffn, &normed);
                    stats.nnz_per_layer[li] += hg.total_nnz();
                    y
                }
            };
            stats.ffn_seconds[li] += ffn_t0.elapsed().as_secs_f64();
            add_inplace(&mut x, &y);
        }
        let x = rmsnorm(&x, &self.ln_final, self.cfg.rmsnorm_eps);
        // tied embeddings: logits = x @ embed^T (contiguous row dots)
        let logits = dense::matmul_nt(&x, &self.embed);
        (logits, stats)
    }

    /// FFN through the configured backend without gate statistics — the
    /// shared dispatch of the decode paths (`kv::decode_step` and
    /// `kv::decode_step_batch`).
    pub(crate) fn ffn_no_stats(&self, layer: &Layer, normed: &Mat) -> Mat {
        forward_backend(&layer.ffn, normed, self.backend == FfnBackend::Twell)
    }

    /// Causal multi-head attention with half-split RoPE (mirrors
    /// python/compile/model.py::_attention; positions start at `0`).
    fn attention(&self, layer: &Layer, x: &Mat, batch: usize, seq: usize)
        -> Mat {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.head_dim();
        let mut q = dense::matmul(x, &layer.wq);
        let mut k = dense::matmul(x, &layer.wk);
        let v = dense::matmul(x, &layer.wv);
        // RoPE applied in place per (b, s, h)
        for b in 0..batch {
            for s in 0..seq {
                let row = b * seq + s;
                rope_row(q.row_mut(row), s, h, dh, &self.rope_inv_freq);
                rope_row(k.row_mut(row), s, h, dh, &self.rope_inv_freq);
            }
        }
        let scale = 1.0 / (dh as f32).sqrt();
        let mut out = Mat::zeros(batch * seq, d);
        par::for_row_blocks_out(batch * seq, d, &mut out.data,
                                |lo, hi, block| {
            let mut scores = vec![0f32; seq];
            for row in lo..hi {
                let b = row / seq;
                let s = row % seq;
                let orow = &mut block[(row - lo) * d..(row - lo + 1) * d];
                for head in 0..h {
                    let qh = &q.row(row)[head * dh..(head + 1) * dh];
                    // causal scores over positions 0..=s
                    let mut maxv = f32::NEG_INFINITY;
                    for t in 0..=s {
                        let kh =
                            &k.row(b * seq + t)[head * dh..(head + 1) * dh];
                        let sc = dense::dot(qh, kh) * scale;
                        scores[t] = sc;
                        maxv = maxv.max(sc);
                    }
                    let mut z = 0f32;
                    for t in 0..=s {
                        scores[t] = (scores[t] - maxv).exp();
                        z += scores[t];
                    }
                    let inv = 1.0 / z;
                    let oh = &mut orow[head * dh..(head + 1) * dh];
                    for t in 0..=s {
                        let w = scores[t] * inv;
                        let vh =
                            &v.row(b * seq + t)[head * dh..(head + 1) * dh];
                        for (o, &vv) in oh.iter_mut().zip(vh) {
                            *o += w * vv;
                        }
                    }
                }
            }
        });
        dense::matmul(&out, &layer.wo)
    }

    /// Per-position log-prob of each target token (cloze scoring):
    /// given tokens (B, S+1), returns (B, S) flat logp of tokens[:,1:].
    pub fn score(&self, tokens: &[u32], batch: usize, seq_plus1: usize)
        -> Vec<f32> {
        let seq = seq_plus1 - 1;
        let inputs: Vec<u32> = (0..batch)
            .flat_map(|b| {
                tokens[b * seq_plus1..b * seq_plus1 + seq].to_vec()
            })
            .collect();
        let (logits, _) = self.forward(&inputs, batch, seq);
        let v = self.cfg.vocab_size;
        let mut out = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            for s in 0..seq {
                let row = logits.row(b * seq + s);
                let target = tokens[b * seq_plus1 + s + 1] as usize;
                let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = row.iter().map(|&x| (x - maxv).exp()).sum();
                out.push(row[target] - maxv - z.ln());
                debug_assert_eq!(row.len(), v);
            }
        }
        out
    }
}

pub(crate) fn rmsnorm(x: &Mat, w: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    rmsnorm_into(x, w, eps, &mut out);
    out
}

/// RMSNorm `x` into a caller-owned `out` (same shape) — the decode
/// scratch path, which replaces the per-layer clone of the residual
/// stream.  Identical arithmetic order to the historical in-place
/// loop, so it is bit-exact with `rmsnorm`.
pub(crate) fn rmsnorm_into(x: &Mat, w: &[f32], eps: f32, out: &mut Mat) {
    debug_assert_eq!((x.rows, x.cols), (out.rows, out.cols));
    for r in 0..x.rows {
        let src = x.row(r);
        let dst = out.row_mut(r);
        let ms: f32 =
            src.iter().map(|&v| v * v).sum::<f32>() / src.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for ((d, &s), &wv) in dst.iter_mut().zip(src).zip(w) {
            *d = s * (inv * wv);
        }
    }
}

/// RMSNorm a matrix in place (the final-norm-over-last-rows case,
/// where the input is already a scratch copy).
pub(crate) fn rmsnorm_inplace(x: &mut Mat, w: &[f32], eps: f32) {
    for r in 0..x.rows {
        let row = x.row_mut(r);
        let ms: f32 =
            row.iter().map(|&v| v * v).sum::<f32>() / row.len() as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (v, &wv) in row.iter_mut().zip(w) {
            *v *= inv * wv;
        }
    }
}

pub(crate) fn add_inplace(a: &mut Mat, b: &Mat) {
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
}

/// The RoPE inverse-frequency table `1 / theta^(i / half)` for one
/// head (all heads share it).  Built once per model at load.
pub(crate) fn rope_inv_freq(dh: usize, theta: f32) -> Vec<f32> {
    let half = dh / 2;
    (0..half)
        .map(|i| 1.0 / theta.powf(i as f32 / half as f32))
        .collect()
}

/// Half-split RoPE on one row of (h * dh) features at position `pos`
/// (matches jax: rotate pairs (i, i + dh/2) within each head).
/// `inv_freq` is the model's precomputed table — the same f32 values
/// the historical per-call `powf` produced, so nothing moves bitwise.
pub(crate) fn rope_row(row: &mut [f32], pos: usize, heads: usize, dh: usize,
            inv_freq: &[f32]) {
    let half = dh / 2;
    debug_assert_eq!(inv_freq.len(), half);
    for head in 0..heads {
        let base = head * dh;
        for (i, &inv) in inv_freq.iter().enumerate() {
            let ang = pos as f32 * inv;
            let (sin, cos) = ang.sin_cos();
            let a = row[base + i];
            let b = row[base + half + i];
            row[base + i] = a * cos - b * sin;
            row[base + half + i] = a * sin + b * cos;
        }
    }
}

#[cfg(test)]
pub(crate) mod tests_support {
    use super::*;
    use crate::util::rng::Pcg32;

    /// The small default test model: big enough to exercise every
    /// decode path, small enough that tests stay fast.
    pub(crate) fn toy_model(backend: FfnBackend) -> Model {
        sized_model(backend, 32, 16, 2, 2, 32, 16, 99)
    }

    /// Parameterized synthetic model.  Tests that need kernel shapes
    /// wide enough to cross the pooled-dispatch work cutoffs (the
    /// decode determinism sweeps) pick bigger dims; everything else
    /// uses `toy_model`.
    pub(crate) fn sized_model(
        backend: FfnBackend, vocab: usize, d: usize, n_layers: usize,
        n_heads: usize, d_ff: usize, tile_n: usize, seed: u64,
    ) -> Model {
        let cfg = ModelConfig {
            name: "toy".into(),
            vocab_size: vocab,
            d_model: d,
            n_layers,
            n_heads,
            d_ff,
            gated: true,
            activation: "relu".into(),
            rope_theta: 10_000.0,
            rmsnorm_eps: 1e-5,
            init_std: 0.05,
            train_batch: 2,
            seq_len: 8,
            score_batch: 2,
            twell_tile_n: tile_n,
            twell_comp: 1,
            ell_width: d_ff,
            dense_backup_frac: 0.25,
        };
        let mut rng = Pcg32::seeded(seed);
        let layers = (0..cfg.n_layers)
            .map(|_| {
                Layer::new(
                    vec![1.0; cfg.d_model],
                    Mat::randn(cfg.d_model, cfg.d_model, 0.05, &mut rng),
                    Mat::randn(cfg.d_model, cfg.d_model, 0.05, &mut rng),
                    Mat::randn(cfg.d_model, cfg.d_model, 0.05, &mut rng),
                    Mat::randn(cfg.d_model, cfg.d_model, 0.05, &mut rng),
                    vec![1.0; cfg.d_model],
                    FfnWeights::random(
                        cfg.d_model, cfg.d_ff, 0.05, &mut rng,
                        cfg.twell_tile_n, 1, cfg.ell_width, 0.25,
                    ),
                )
            })
            .collect();
        let embed = Mat::randn(cfg.vocab_size, cfg.d_model, 0.05, &mut rng);
        let ln_final = vec![1.0; cfg.d_model];
        Model::assemble(cfg, embed, layers, ln_final, backend, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::tests_support::toy_model;
    use super::*;

    #[test]
    fn forward_shapes_and_finite() {
        let m = toy_model(FfnBackend::Dense);
        let tokens: Vec<u32> = (0..16).map(|i| i % 32).collect();
        let (logits, stats) = m.forward(&tokens, 2, 8);
        assert_eq!(logits.rows, 16);
        assert_eq!(logits.cols, 32);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        assert_eq!(stats.tokens, 16);
        assert_eq!(stats.nnz_per_layer.len(), 2);
    }

    #[test]
    fn twell_backend_matches_dense_backend() {
        let md = toy_model(FfnBackend::Dense);
        let mut mt = toy_model(FfnBackend::Twell);
        mt.backend = FfnBackend::Twell;
        let tokens: Vec<u32> = (0..24).map(|i| (i * 7) % 32).collect();
        let (ld, sd) = md.forward(&tokens, 3, 8);
        let (lt, st) = mt.forward(&tokens, 3, 8);
        assert!(lt.rel_err(&ld) < 1e-4, "{}", lt.rel_err(&ld));
        assert_eq!(sd.nnz_per_layer, st.nnz_per_layer);
    }

    #[test]
    fn causality_prefix_invariance() {
        // changing a future token must not affect earlier logits
        let m = toy_model(FfnBackend::Dense);
        let mut a: Vec<u32> = (0..8).collect();
        let (la, _) = m.forward(&a, 1, 8);
        a[7] = 31;
        let (lb, _) = m.forward(&a, 1, 8);
        for s in 0..7 {
            for vv in 0..32 {
                assert!((la.at(s, vv) - lb.at(s, vv)).abs() < 1e-5,
                        "position {s} leaked future info");
            }
        }
    }

    #[test]
    fn score_is_log_prob() {
        let m = toy_model(FfnBackend::Dense);
        let tokens: Vec<u32> = (0..18).map(|i| i % 32).collect();
        let logp = m.score(&tokens, 2, 9);
        assert_eq!(logp.len(), 16);
        assert!(logp.iter().all(|&v| v < 0.0));
        // sums over the vocab to ~1 by construction of log-softmax; spot
        // check magnitude near uniform for random weights
        let mean = logp.iter().sum::<f32>() / 16.0;
        assert!((mean + (32f32).ln()).abs() < 2.0, "{mean}");
    }

    #[test]
    fn rope_table_matches_per_call_powf() {
        // the precomputed table must hold the exact f32 the old inline
        // powf produced, position by position
        let (dh, theta) = (8usize, 10_000.0f32);
        let inv = rope_inv_freq(dh, theta);
        let half = dh / 2;
        assert_eq!(inv.len(), half);
        for (i, &v) in inv.iter().enumerate() {
            let expect = 1.0 / theta.powf(i as f32 / half as f32);
            assert_eq!(v.to_bits(), expect.to_bits(), "freq {i}");
        }
    }

    #[test]
    fn fused_qkv_weight_is_the_three_projections() {
        let m = toy_model(FfnBackend::Dense);
        let d = m.cfg.d_model;
        let l = &m.layers[0];
        assert_eq!((l.wqkv.rows, l.wqkv.cols), (d, 3 * d));
        for r in 0..d {
            assert_eq!(&l.wqkv.row(r)[..d], l.wq.row(r));
            assert_eq!(&l.wqkv.row(r)[d..2 * d], l.wk.row(r));
            assert_eq!(&l.wqkv.row(r)[2 * d..], l.wv.row(r));
        }
    }

    #[test]
    fn rmsnorm_variants_agree_bitwise() {
        let m = toy_model(FfnBackend::Dense);
        let x = Mat::randn(5, 16, 1.0,
                           &mut crate::util::rng::Pcg32::seeded(3));
        let w: Vec<f32> = (0..16).map(|i| 0.5 + i as f32 * 0.1).collect();
        let a = rmsnorm(&x, &w, m.cfg.rmsnorm_eps);
        let mut b = Mat::zeros(5, 16);
        rmsnorm_into(&x, &w, m.cfg.rmsnorm_eps, &mut b);
        let mut c = x.clone();
        rmsnorm_inplace(&mut c, &w, m.cfg.rmsnorm_eps);
        assert_eq!(a.data, b.data);
        assert_eq!(a.data, c.data);
    }

    #[test]
    fn batch_independence() {
        let m = toy_model(FfnBackend::Dense);
        let seq_a: Vec<u32> = (0..8).collect();
        let seq_b: Vec<u32> = (8..16).collect();
        let (solo, _) = m.forward(&seq_a, 1, 8);
        let both: Vec<u32> =
            seq_a.iter().chain(seq_b.iter()).cloned().collect();
        let (batched, _) = m.forward(&both, 2, 8);
        for s in 0..8 {
            for vv in 0..32 {
                assert!((solo.at(s, vv) - batched.at(s, vv)).abs() < 1e-5);
            }
        }
    }
}
