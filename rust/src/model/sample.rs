//! Per-request stochastic decoding: temperature / top-k / top-p
//! (nucleus) sampling over next-token logits, with a seeded
//! SplitMix64 RNG so every completion is reproducible.
//!
//! The logits-processor pipeline runs **temperature → top-k → top-p →
//! sample**, and the order matters:
//!
//! * **Temperature first**: dividing logits by `temperature` reshapes
//!   the whole distribution (t < 1 sharpens, t > 1 flattens).  It is a
//!   monotonic map, so it never changes *which* tokens survive top-k,
//!   but it changes the probability mass the later nucleus cut
//!   measures — so it must run before softmax, not after.
//! * **Top-k before top-p**: top-k is defined on logit *rank* and
//!   needs no normalization, so it runs on (scaled) logits directly.
//!   Running it after the nucleus cut could silently widen the
//!   nucleus: top-p would spread mass over tokens top-k was about to
//!   delete, and the renormalization after deletion would no longer
//!   match the "smallest prefix with cumulative probability ≥ p"
//!   contract.
//! * **Top-p after softmax**: the nucleus is defined over
//!   *probabilities* ("smallest prefix of the sorted distribution
//!   whose cumulative mass reaches `top_p`"), so it must see the
//!   normalized distribution of the top-k survivors — then the kept
//!   prefix is renormalized and sampled.
//!
//! `temperature == 0` short-circuits the whole pipeline to
//! [`crate::model::kv::argmax`] (lowest index wins on ties) without
//! consuming any randomness, so greedy requests stay bit-exact with
//! the pre-sampling serving paths.
//!
//! The softmax subtracts the max logit before exponentiating, so
//! extreme logits (±1e4, all-equal, a single finite entry among
//! `-inf`) never produce NaN/inf — the property tests below are the
//! contract.
//!
//! ```
//! use repro::model::sample::{Sampler, SamplingParams};
//!
//! let params = SamplingParams {
//!     temperature: 0.8, top_k: 2, top_p: 0.9, seed: 7,
//! };
//! let logits = [0.0_f32, 1.0, 3.0, 2.5];
//! // same seed -> same stream, token always inside the top-k set
//! let (mut a, mut b) = (Sampler::new(params), Sampler::new(params));
//! for _ in 0..16 {
//!     let t = a.sample(&logits);
//!     assert_eq!(t, b.sample(&logits));
//!     assert!(t == 2 || t == 3, "outside the top-2 set: {t}");
//! }
//! // temperature 0 is exactly argmax, regardless of top-k / top-p
//! let mut greedy = Sampler::new(SamplingParams::greedy());
//! assert_eq!(greedy.sample(&logits), 2);
//! ```

use anyhow::{ensure, Result};

use crate::model::kv::argmax;
use crate::util::rng::cumulative_pick;

/// Per-request sampling controls, carried alongside the prompt through
/// the serving stack (`serve::Request`).
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// Softmax temperature; `0` means greedy (argmax).
    pub temperature: f32,
    /// Keep only the `top_k` highest logits before softmax
    /// (`0` disables the filter).
    pub top_k: usize,
    /// Nucleus mass: keep the smallest probability-sorted prefix whose
    /// cumulative mass reaches `top_p` (`1` disables the cut).
    pub top_p: f32,
    /// Seed of the request's private RNG; the same seed and prompt
    /// reproduce the same completion on every scheduler path.
    pub seed: u64,
}

impl SamplingParams {
    /// Greedy decoding: `temperature == 0`, no truncation, seed 0.
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }

    /// Greedy requests short-circuit the pipeline to `argmax`.
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }

    /// Range checks, done once at the submit boundary so a bad request
    /// fails with an actionable error instead of a worker panic.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            self.temperature.is_finite() && self.temperature >= 0.0,
            "temperature must be finite and >= 0, got {}",
            self.temperature
        );
        ensure!(
            self.top_p > 0.0 && self.top_p <= 1.0,
            "top_p must be in (0, 1], got {}",
            self.top_p
        );
        Ok(())
    }
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams::greedy()
    }
}

/// SplitMix64 (Steele et al. 2014): one 64-bit add + mix per draw.
/// Each request owns one, seeded from its `SamplingParams::seed`, so
/// completions are reproducible no matter how the scheduler interleaves
/// them — the generator is never shared across requests.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Per-request sampler: the processor pipeline plus the request's
/// private RNG.  One `sample` call consumes exactly one uniform draw
/// (none when greedy), so the token stream depends only on the logits
/// sequence — which is why sequential and batched scheduling produce
/// identical streams for the same seed.
pub struct Sampler {
    params: SamplingParams,
    rng: SplitMix64,
    /// Candidate scratch reused across tokens: `sample` runs on the
    /// hot decode loop, and rebuilding a vocab-sized Vec per sampled
    /// token would reintroduce exactly the per-step allocation PR 3
    /// hoisted out of `attend_one`.
    scratch: Vec<(usize, f32)>,
}

impl Sampler {
    pub fn new(params: SamplingParams) -> Sampler {
        Sampler {
            params,
            rng: SplitMix64::new(params.seed),
            scratch: Vec::new(),
        }
    }

    pub fn params(&self) -> &SamplingParams {
        &self.params
    }

    /// Sample the next token index from `logits`.  `temperature == 0`
    /// short-circuits to `argmax` (lowest index wins ties) without
    /// touching the RNG.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.params.is_greedy() {
            return argmax(logits);
        }
        process_logits_into(&mut self.scratch, logits, &self.params);
        let total: f64 =
            self.scratch.iter().map(|&(_, p)| p as f64).sum();
        let i = cumulative_pick(
            self.rng.f64() * total,
            self.scratch.iter().map(|&(_, p)| p as f64),
        );
        self.scratch[i].0
    }
}

/// The pipeline minus the draw: temperature → top-k → softmax → top-p.
/// Returns `(token, probability)` candidates sorted by probability
/// descending (ties broken toward the lower token index), renormalized
/// to sum to 1.  Requires `temperature > 0` — greedy requests never
/// reach the pipeline.
pub fn process_logits(
    logits: &[f32], params: &SamplingParams,
) -> Vec<(usize, f32)> {
    let mut cands = Vec::new();
    process_logits_into(&mut cands, logits, params);
    cands
}

/// Allocation-reusing form of `process_logits`: clears and refills
/// `cands` in place, so a per-request `Sampler` pays for the candidate
/// buffer once, not once per token.
pub fn process_logits_into(
    cands: &mut Vec<(usize, f32)>, logits: &[f32], params: &SamplingParams,
) {
    assert!(params.temperature > 0.0,
            "temperature 0 short-circuits to argmax before the pipeline");
    top_k_into(cands, logits, params.top_k);
    softmax_candidates(cands, params.temperature);
    top_p_truncate(cands, params.top_p);
}

/// Keep the `k` largest logits (`k == 0` or `k >= len`: keep all),
/// sorted descending.  Equal logits keep ascending token order, so
/// truncation at a tie is deterministic and matches `argmax`'s
/// lowest-index-wins rule.
pub fn top_k_candidates(logits: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut cands = Vec::new();
    top_k_into(&mut cands, logits, k);
    cands
}

/// `top_k_candidates` into a reused buffer.
pub fn top_k_into(
    cands: &mut Vec<(usize, f32)>, logits: &[f32], k: usize,
) {
    cands.clear();
    cands.extend(logits.iter().copied().enumerate());
    cands.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    if k > 0 && k < cands.len() {
        cands.truncate(k);
    }
}

/// Replace each candidate's logit with its temperature-scaled softmax
/// probability.  The max logit (the first candidate — the slice is
/// sorted descending) is subtracted before exponentiating, so every
/// exponent is <= 0 and extreme logits stay finite.  If the input is
/// degenerate (every term underflows, or non-finite logits poison the
/// max), all mass collapses onto the largest logit instead of emitting
/// NaNs.
pub fn softmax_candidates(cands: &mut [(usize, f32)], temperature: f32) {
    assert!(temperature > 0.0, "softmax needs a positive temperature");
    if cands.is_empty() {
        return;
    }
    let max = cands[0].1;
    let mut sum = 0f64;
    for c in cands.iter_mut() {
        let e = (((c.1 - max) / temperature) as f64).exp();
        c.1 = if e.is_finite() { e as f32 } else { 0.0 };
        sum += c.1 as f64;
    }
    if sum > 0.0 && sum.is_finite() {
        for c in cands.iter_mut() {
            c.1 = (c.1 as f64 / sum) as f32;
        }
    } else {
        for c in cands.iter_mut() {
            c.1 = 0.0;
        }
        cands[0].1 = 1.0;
    }
}

/// Nucleus cut: keep the smallest prefix of the probability-sorted
/// candidates whose cumulative mass reaches `top_p` — never fewer than
/// one — then renormalize the survivors to sum to 1.  `top_p >= 1`
/// keeps everything (the distribution is already normalized).
pub fn top_p_truncate(cands: &mut Vec<(usize, f32)>, top_p: f32) {
    assert!(top_p > 0.0, "top_p must be positive");
    if top_p >= 1.0 || cands.is_empty() {
        return;
    }
    let mut keep = cands.len();
    let mut cum = 0f64;
    for (i, &(_, p)) in cands.iter().enumerate() {
        cum += p as f64;
        if cum >= top_p as f64 {
            keep = i + 1;
            break;
        }
    }
    cands.truncate(keep);
    let sum: f64 = cands.iter().map(|&(_, p)| p as f64).sum();
    if sum > 0.0 {
        for c in cands.iter_mut() {
            c.1 = (c.1 as f64 / sum) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    /// |sum(probs) - 1| <= tol, every prob finite and in [0, 1].
    fn assert_normalized(cands: &[(usize, f32)], what: &str)
        -> Result<(), String> {
        let mut sum = 0f64;
        for &(i, p) in cands {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("{what}: prob {p} at token {i}"));
            }
            sum += p as f64;
        }
        if (sum - 1.0).abs() > 1e-5 {
            return Err(format!("{what}: probs sum to {sum}"));
        }
        Ok(())
    }

    fn params(g: &mut Gen, top_k: usize, top_p: f32) -> SamplingParams {
        SamplingParams {
            temperature: g.f32_in(0.05, 2.0),
            top_k,
            top_p,
            seed: g.rng.next_u64(),
        }
    }

    #[test]
    fn prop_sampled_index_is_within_the_top_k_set() {
        check("top-k membership", 100, 17, |g: &mut Gen| {
            let n = g.usize_in(2, 64);
            let logits = g.vec_normal(n, 2.0);
            let k = g.usize_in(1, n);
            let p = params(g, k, 1.0);
            let mut s = Sampler::new(p);
            let idx = s.sample(&logits);
            // reference top-k set, ties broken toward lower index
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
            });
            if !order[..k].contains(&idx) {
                return Err(format!("token {idx} outside top-{k}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_nucleus_is_smallest_prefix_reaching_top_p() {
        check("nucleus minimality", 100, 23, |g: &mut Gen| {
            let n = g.usize_in(2, 64);
            let logits = g.vec_normal(n, 2.0);
            let t = g.f32_in(0.2, 2.0);
            let top_p = g.f32_in(0.05, 0.999);
            let mut cands = top_k_candidates(&logits, 0);
            softmax_candidates(&mut cands, t);
            let before = cands.clone();
            top_p_truncate(&mut cands, top_p);
            let kept = cands.len();
            if kept == 0 {
                return Err("nucleus emptied the distribution".into());
            }
            // kept prefix reaches top_p (unless the whole set was kept
            // because rounding never got there)
            let mass = |m: usize| -> f64 {
                before[..m].iter().map(|&(_, p)| p as f64).sum()
            };
            if kept < before.len() && mass(kept) < top_p as f64 {
                return Err(format!(
                    "kept {kept} with mass {} < top_p {top_p}",
                    mass(kept)
                ));
            }
            // ...and it is the *smallest* such prefix
            if kept > 1 && mass(kept - 1) >= top_p as f64 {
                return Err(format!(
                    "prefix {} already reached top_p {top_p}",
                    kept - 1
                ));
            }
            // kept tokens are exactly the head of the sorted order
            for (a, b) in cands.iter().zip(&before) {
                if a.0 != b.0 {
                    return Err("nucleus reordered candidates".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_probs_sum_to_one_after_each_processor() {
        check("normalization", 100, 31, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let mut logits = g.vec_normal(n, 3.0);
            // sprinkle extremes so renormalization sees hard inputs
            if g.bool() {
                let i = g.rng.usize_below(n);
                logits[i] = *g.choose(&[1e4, -1e4, f32::NEG_INFINITY]);
            }
            let t = g.f32_in(0.05, 2.0);
            let k = g.usize_in(0, n);
            let mut cands = top_k_candidates(&logits, k);
            softmax_candidates(&mut cands, t);
            assert_normalized(&cands, "after softmax")?;
            top_p_truncate(&mut cands, g.f32_in(0.05, 1.0));
            assert_normalized(&cands, "after top-p")?;
            Ok(())
        });
    }

    #[test]
    fn extreme_logits_never_produce_nan_inf_or_panic() {
        // the contract cases: ±1e4, all-equal, single finite entry
        let cases: Vec<Vec<f32>> = vec![
            vec![1e4, -1e4, 0.0, 5.0],
            vec![-1e4, -1e4, -1e4],
            vec![2.5; 8],
            vec![f32::NEG_INFINITY, 3.0, f32::NEG_INFINITY],
            vec![f32::NEG_INFINITY, f32::NEG_INFINITY, -7.0],
        ];
        for logits in &cases {
            for &t in &[0.01f32, 0.7, 1.0, 10.0] {
                for &(k, p) in &[(0usize, 1.0f32), (2, 0.5), (1, 0.9)] {
                    let sp = SamplingParams {
                        temperature: t, top_k: k, top_p: p, seed: 9,
                    };
                    let cands = process_logits(logits, &sp);
                    assert_normalized(&cands, "extreme").unwrap();
                    let mut s = Sampler::new(sp);
                    for _ in 0..8 {
                        let idx = s.sample(logits);
                        assert!(idx < logits.len());
                    }
                }
            }
        }
    }

    #[test]
    fn prop_same_seed_reproduces_the_same_picks() {
        check("seed determinism", 50, 41, |g: &mut Gen| {
            let n = g.usize_in(2, 32);
            let p = params(g, g.usize_in(0, n), g.f32_in(0.1, 1.0));
            let mut a = Sampler::new(p);
            let mut b = Sampler::new(p);
            for _ in 0..16 {
                let logits = g.vec_normal(n, 2.0);
                if a.sample(&logits) != b.sample(&logits) {
                    return Err("same seed diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn temperature_zero_is_argmax_and_consumes_no_randomness() {
        let logits = vec![0.3f32, 0.9, 0.9, -2.0];
        let sp = SamplingParams {
            temperature: 0.0, top_k: 2, top_p: 0.4, seed: 77,
        };
        let mut s = Sampler::new(sp);
        for _ in 0..4 {
            // ties break to the lowest index, exactly like argmax
            assert_eq!(s.sample(&logits), 1);
        }
        // the RNG was never advanced: a fresh sampler's first draw
        // matches this one's
        assert_eq!(s.rng.next_u64(), SplitMix64::new(77).next_u64());
    }

    #[test]
    fn top_k_one_is_greedy_for_any_temperature() {
        let logits = vec![-0.5f32, 2.0, 1.9, 0.0];
        let sp = SamplingParams {
            temperature: 5.0, top_k: 1, top_p: 1.0, seed: 3,
        };
        let mut s = Sampler::new(sp);
        for _ in 0..16 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn top_k_zero_and_top_p_one_keep_the_full_distribution() {
        let logits = vec![0.1f32, 0.2, 0.3];
        let sp = SamplingParams {
            temperature: 1.0, top_k: 0, top_p: 1.0, seed: 1,
        };
        let cands = process_logits(&logits, &sp);
        assert_eq!(cands.len(), 3);
        // sorted descending: token 2, 1, 0
        assert_eq!(
            cands.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
            vec![2, 1, 0]
        );
    }

    #[test]
    fn equal_logits_truncate_toward_the_lowest_indices() {
        let cands = top_k_candidates(&[1.0, 1.0, 1.0, 1.0], 2);
        assert_eq!(
            cands.iter().map(|&(i, _)| i).collect::<Vec<_>>(),
            vec![0, 1]
        );
    }

    #[test]
    fn splitmix_is_deterministic_and_uniformish() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        let mut mean = 0f64;
        for _ in 0..4096 {
            let x = a.f64();
            assert_eq!(x, b.f64());
            assert!((0.0..1.0).contains(&x));
            mean += x;
        }
        mean /= 4096.0;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn validate_rejects_bad_ranges() {
        let ok = SamplingParams {
            temperature: 0.8, top_k: 5, top_p: 0.9, seed: 0,
        };
        assert!(ok.validate().is_ok());
        assert!(SamplingParams { temperature: -1.0, ..ok }
            .validate()
            .is_err());
        assert!(SamplingParams { temperature: f32::NAN, ..ok }
            .validate()
            .is_err());
        assert!(SamplingParams { top_p: 0.0, ..ok }.validate().is_err());
        assert!(SamplingParams { top_p: 1.5, ..ok }.validate().is_err());
        assert!(SamplingParams::greedy().validate().is_ok());
    }
}
