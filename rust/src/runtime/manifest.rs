//! AOT manifest parsing — the io contract written by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub config: ModelConfig,
    pub scan_k: usize,
    pub l1_grid: Vec<f64>,
    pub params: Vec<ParamSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_specs(j: &Json) -> Result<Vec<IoSpec>> {
    j.as_arr()?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                shape: e.get("shape")?.usize_vec()?,
                dtype: e.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    pub fn read(path: &Path) -> Result<Manifest> {
        let j = Json::read_file(path)
            .with_context(|| format!("manifest {path:?}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let params = j
            .get("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.usize_vec()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut artifacts = BTreeMap::new();
        if let Json::Obj(m) = j.get("artifacts")? {
            for (name, art) in m {
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        file: art.get("file")?.as_str()?.to_string(),
                        inputs: io_specs(art.get("inputs")?)?,
                        outputs: io_specs(art.get("outputs")?)?,
                    },
                );
            }
        }
        Ok(Manifest {
            preset: j.get("preset")?.as_str()?.to_string(),
            config: ModelConfig::from_json(j.get("config")?)?,
            scan_k: j.get("scan_k")?.as_usize()?,
            l1_grid: j.get("l1_grid")?.f64_vec()?,
            params,
            artifacts,
        })
    }

    /// Total parameter count (sanity check against config.param_count()).
    pub fn total_params(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "preset": "t",
        "config": {"name":"t","vocab_size":256,"d_model":64,"n_layers":2,
                   "n_heads":2,"d_ff":176,"gated":true,"activation":"relu",
                   "rope_theta":10000.0,"tied_embeddings":true,
                   "rmsnorm_eps":1e-05,"init_std":0.02,"train_batch":4,
                   "seq_len":64,"score_batch":8,"twell_tile_n":16,
                   "twell_comp":4,"ell_width":64,"dense_backup_frac":0.125},
        "scan_k": 8,
        "l1_grid": [0.0, 1e-05],
        "params": [{"name":"embed","shape":[256,64]},
                   {"name":"ln_final","shape":[64]}],
        "artifacts": {
            "init": {"file":"init.hlo.txt",
                     "inputs":[{"shape":[],"dtype":"i32"}],
                     "outputs":[{"shape":[256,64],"dtype":"f32"},
                                {"shape":[64],"dtype":"f32"}]}
        }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.preset, "t");
        assert_eq!(m.config.d_model, 64);
        assert_eq!(m.scan_k, 8);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.total_params(), 256 * 64 + 64);
        let init = &m.artifacts["init"];
        assert_eq!(init.inputs[0].dtype, "i32");
        assert_eq!(init.outputs[0].shape, vec![256, 64]);
    }

    #[test]
    fn real_manifest_if_built() {
        // integration check against the actual artifacts, when present
        let p = crate::config::default_paths().manifest("tiny");
        if !p.exists() {
            return;
        }
        let m = Manifest::read(&p).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.total_params(), m.config.param_count());
        for key in ["init", "train_step", "train_step8", "forward", "score",
                    "forward_stats", "reinit"] {
            assert!(m.artifacts.contains_key(key), "{key}");
        }
    }
}
