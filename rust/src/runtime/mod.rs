//! PJRT runtime: load the AOT HLO-text artifacts and execute them from
//! the rust hot path (python never runs at request time).
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! the 64-bit instruction ids that xla_extension 0.5.1 would otherwise
//! reject (see /opt/xla-example/README.md and python/compile/aot.py).

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, IoSpec, Manifest};

/// Process-wide PJRT CPU client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?,
            exes: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached by absolute path).
    pub fn load(&mut self, path: &Path) -> Result<()> {
        let key = path.to_string_lossy().to_string();
        if self.exes.contains_key(&key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        self.exes.insert(key, exe);
        Ok(())
    }

    /// Execute a loaded artifact.  AOT functions are lowered with
    /// `return_tuple=True`, so the single output buffer is a tuple that we
    /// decompose into one Literal per logical output.
    pub fn call(&mut self, path: &Path, args: &[&xla::Literal])
        -> Result<Vec<xla::Literal>> {
        self.load(path)?;
        let key = path.to_string_lossy().to_string();
        let exe = self.exes.get(&key).unwrap();
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {path:?}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }
}

// ---------------------------------------------------------------------------
// Literal construction / extraction helpers
// ---------------------------------------------------------------------------

pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("shape {shape:?} wants {n} elements, got {}", data.len());
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product::<usize>().max(1);
    if data.len() != n {
        bail!("shape {shape:?} wants {n} elements, got {}", data.len());
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(to_f32_vec(lit)?[0])
}

// ---------------------------------------------------------------------------
// Model bundle: manifest + artifact paths + parameter state
// ---------------------------------------------------------------------------

/// A preset's compiled model: manifest metadata plus helpers to call the
/// standard artifacts with the canonical argument layout.
pub struct ModelBundle {
    pub manifest: Manifest,
    pub dir: std::path::PathBuf,
}

impl ModelBundle {
    pub fn open(artifacts_root: &Path, preset: &str) -> Result<ModelBundle> {
        let dir = artifacts_root.join(preset);
        let manifest = Manifest::read(&dir.join("manifest.json"))?;
        Ok(ModelBundle { manifest, dir })
    }

    pub fn artifact_path(&self, name: &str) -> Result<std::path::PathBuf> {
        let art = self
            .manifest
            .artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?;
        Ok(self.dir.join(&art.file))
    }

    pub fn n_params(&self) -> usize {
        self.manifest.params.len()
    }

    /// init(seed) -> params
    pub fn init(&self, rt: &mut Runtime, seed: i32)
        -> Result<Vec<xla::Literal>> {
        let path = self.artifact_path("init")?;
        let seed = scalar_i32(seed);
        rt.call(&path, &[&seed])
    }
}

/// Training-step outputs beyond the new optimizer state.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    pub ce: f32,
    pub l1: f32,
    /// per-layer mean nnz per token
    pub nnz: Vec<f32>,
    /// per-(layer, neuron) activation counts this step, flattened [L*F]
    pub active: Vec<f32>,
    pub grad_norm: f32,
}

/// Full optimizer state held as literals on the host side.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub ms: Vec<xla::Literal>,
    pub vs: Vec<xla::Literal>,
    pub step: usize,
}

impl TrainState {
    /// Fresh state: init params + zeroed moments.
    pub fn init(bundle: &ModelBundle, rt: &mut Runtime, seed: i32)
        -> Result<TrainState> {
        let params = bundle.init(rt, seed)?;
        let mut ms = Vec::with_capacity(params.len());
        let mut vs = Vec::with_capacity(params.len());
        for spec in &bundle.manifest.params {
            let zeros = vec![0f32; spec.shape.iter().product::<usize>()];
            ms.push(lit_f32(&zeros, &spec.shape)?);
            vs.push(lit_f32(&zeros, &spec.shape)?);
        }
        Ok(TrainState { params, ms, vs, step: 0 })
    }

    /// Rebuild a state from checkpointed parameters (zeroed moments) —
    /// used by `repro analyze` / `repro eval` on saved runs.
    pub fn from_params(bundle: &ModelBundle, params: &[Vec<f32>])
        -> Result<TrainState> {
        anyhow::ensure!(params.len() == bundle.manifest.params.len());
        let mut lits = Vec::with_capacity(params.len());
        let mut ms = Vec::with_capacity(params.len());
        let mut vs = Vec::with_capacity(params.len());
        for (p, spec) in params.iter().zip(&bundle.manifest.params) {
            lits.push(lit_f32(p, &spec.shape)?);
            let zeros = vec![0f32; p.len()];
            ms.push(lit_f32(&zeros, &spec.shape)?);
            vs.push(lit_f32(&zeros, &spec.shape)?);
        }
        Ok(TrainState { params: lits, ms, vs, step: 0 })
    }

    /// One optimizer step through the `train_step` artifact.
    pub fn step(
        &mut self, bundle: &ModelBundle, rt: &mut Runtime, tokens: &[i32],
        lr: f32, l1_coeff: f32,
    ) -> Result<StepStats> {
        let cfg = &bundle.manifest.config;
        let tok = lit_i32(tokens, &[cfg.train_batch, cfg.seq_len + 1])?;
        let lr_l = scalar_f32(lr);
        let l1_l = scalar_f32(l1_coeff);
        let step_l = scalar_f32(self.step as f32);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.ms.iter());
        args.extend(self.vs.iter());
        args.push(&tok);
        args.push(&lr_l);
        args.push(&l1_l);
        args.push(&step_l);
        let path = bundle.artifact_path("train_step")?;
        let mut out = rt.call(&path, &args)?;
        let n = bundle.n_params();
        anyhow::ensure!(out.len() == 3 * n + 6, "unexpected output arity");
        let tail = out.split_off(3 * n);
        let vs = out.split_off(2 * n);
        let ms = out.split_off(n);
        self.params = out;
        self.ms = ms;
        self.vs = vs;
        self.step += 1;
        Ok(StepStats {
            loss: to_f32_scalar(&tail[0])?,
            ce: to_f32_scalar(&tail[1])?,
            l1: to_f32_scalar(&tail[2])?,
            nnz: to_f32_vec(&tail[3])?,
            active: to_f32_vec(&tail[4])?,
            grad_norm: to_f32_scalar(&tail[5])?,
        })
    }

    /// `scan_k` fused optimizer steps through `train_step8` (one PJRT
    /// round-trip; §Perf L2 optimization).  Returns per-substep stats with
    /// `active` counts summed over the window attached to the last one.
    pub fn step_k(
        &mut self, bundle: &ModelBundle, rt: &mut Runtime, tokens: &[i32],
        lrs: &[f32], l1_coeff: f32,
    ) -> Result<Vec<StepStats>> {
        let cfg = &bundle.manifest.config;
        let k = bundle.manifest.scan_k;
        anyhow::ensure!(lrs.len() == k, "need {k} learning rates");
        let tok = lit_i32(tokens, &[k, cfg.train_batch, cfg.seq_len + 1])?;
        let lr_l = lit_f32(lrs, &[k])?;
        let l1_l = scalar_f32(l1_coeff);
        let step_l = scalar_f32(self.step as f32);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.ms.iter());
        args.extend(self.vs.iter());
        args.push(&tok);
        args.push(&lr_l);
        args.push(&l1_l);
        args.push(&step_l);
        let path = bundle.artifact_path("train_step8")?;
        let mut out = rt.call(&path, &args)?;
        let n = bundle.n_params();
        anyhow::ensure!(out.len() == 3 * n + 5, "unexpected output arity");
        let tail = out.split_off(3 * n);
        let vs = out.split_off(2 * n);
        let ms = out.split_off(n);
        self.params = out;
        self.ms = ms;
        self.vs = vs;
        self.step += k;
        // tail: loss[k], ce[k], nnz[k,L], active[L,F] (summed), gnorm[k]
        let loss = to_f32_vec(&tail[0])?;
        let ce = to_f32_vec(&tail[1])?;
        let nnz = to_f32_vec(&tail[2])?;
        let active = to_f32_vec(&tail[3])?;
        let gnorm = to_f32_vec(&tail[4])?;
        let layers = cfg.n_layers;
        let mut stats = Vec::with_capacity(k);
        for i in 0..k {
            stats.push(StepStats {
                loss: loss[i],
                ce: ce[i],
                l1: 0.0,
                nnz: nnz[i * layers..(i + 1) * layers].to_vec(),
                active: if i + 1 == k { active.clone() } else { vec![] },
                grad_norm: gnorm[i],
            });
        }
        Ok(stats)
    }

    /// Dead-neuron targeted reinitialization (`reinit` artifact, eq. 6).
    pub fn reinit(
        &mut self, bundle: &ModelBundle, rt: &mut Runtime, active: &[f32],
        seed: i32, lambda: f32,
    ) -> Result<()> {
        let cfg = &bundle.manifest.config;
        let act = lit_f32(active, &[cfg.n_layers, cfg.d_ff])?;
        let seed_l = scalar_i32(seed);
        let lam_l = scalar_f32(lambda);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.push(&act);
        args.push(&seed_l);
        args.push(&lam_l);
        let path = bundle.artifact_path("reinit")?;
        let out = rt.call(&path, &args)?;
        anyhow::ensure!(out.len() == bundle.n_params());
        self.params = out;
        Ok(())
    }

    /// Cloze scoring: per-position target log-probs + per-layer nnz.
    pub fn score(
        &self, bundle: &ModelBundle, rt: &mut Runtime, tokens: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let cfg = &bundle.manifest.config;
        let tok = lit_i32(tokens, &[cfg.score_batch, cfg.seq_len + 1])?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.push(&tok);
        let path = bundle.artifact_path("score")?;
        let out = rt.call(&path, &args)?;
        Ok((to_f32_vec(&out[0])?, to_f32_vec(&out[1])?))
    }

    /// Per-layer per-position nnz stats ([L, B, S] flattened).
    pub fn forward_stats(
        &self, bundle: &ModelBundle, rt: &mut Runtime, tokens: &[i32],
    ) -> Result<Vec<f32>> {
        let cfg = &bundle.manifest.config;
        let tok = lit_i32(tokens, &[cfg.score_batch, cfg.seq_len])?;
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.push(&tok);
        let path = bundle.artifact_path("forward_stats")?;
        let out = rt.call(&path, &args)?;
        to_f32_vec(&out[0])
    }

    /// Extract all parameters as host vectors (checkpoint export).
    pub fn params_f32(&self) -> Result<Vec<Vec<f32>>> {
        self.params.iter().map(to_f32_vec).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32_vec(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let s = scalar_f32(7.5);
        assert_eq!(to_f32_scalar(&s).unwrap(), 7.5);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2, 2]).is_err());
    }
}
