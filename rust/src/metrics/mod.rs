//! Measurement substrates: FLOP accounting, the analytical energy model
//! (nvidia-smi stand-in, DESIGN.md section 1), and activation-memory
//! accounting for the dense / TwELL / ELL / hybrid formats (figure 1 and
//! the Table 1 peak-memory column).

pub mod energy;
pub mod flops;
pub mod memory;
