//! Analytical energy model — the nvidia-smi power-draw stand-in
//! (DESIGN.md section 1).
//!
//! The paper measures wall-socket GPU energy; its savings decompose into
//! (a) fewer FLOPs executed and (b) less DRAM traffic, both scaled by a
//! constant idle/static power share that throughput gains amortize.  We
//! charge exactly those terms:
//!
//! ```text
//! E = flops * e_flop + dram_bytes * e_byte + t_exec * p_static
//! ```
//!
//! with constants calibrated to public H100 figures (~700 W TDP at
//! ~990 bf16 TFLOP/s dense => ~0.7 pJ/FLOP at full tilt, of which ~40% is
//! static/idle; HBM3 access ~7 pJ/byte).  The absolute joules are a
//! model; the *relative* savings (figure 4 / table 1) are what the
//! reproduction tracks.

#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub pj_per_flop: f64,
    pub pj_per_dram_byte: f64,
    pub static_watts: f64,
}

pub const H100_PCIE: EnergyModel = EnergyModel {
    pj_per_flop: 0.45,
    pj_per_dram_byte: 7.0,
    static_watts: 120.0,
};

pub const RTX6000: EnergyModel = EnergyModel {
    pj_per_flop: 0.75,
    pj_per_dram_byte: 9.0,
    static_watts: 90.0,
};

impl EnergyModel {
    /// Energy in joules for an execution of `flops` FLOPs moving
    /// `dram_bytes` bytes over `seconds` of wall-clock.
    pub fn joules(&self, flops: u64, dram_bytes: u64, seconds: f64) -> f64 {
        flops as f64 * self.pj_per_flop * 1e-12
            + dram_bytes as f64 * self.pj_per_dram_byte * 1e-12
            + seconds * self.static_watts
    }

    /// Millijoules per token — the paper's Table 1 unit.
    pub fn mj_per_token(
        &self, flops: u64, dram_bytes: u64, seconds: f64, tokens: u64,
    ) -> f64 {
        self.joules(flops, dram_bytes, seconds) * 1e3 / tokens as f64
    }
}

/// DRAM traffic model for the gated FFN (bytes, f32 elements = 4 bytes;
/// the paper uses bf16=2 — the ratio cancels in relative comparisons).
pub fn ffn_dense_bytes(m: usize, k: usize, n: usize, elt: usize) -> u64 {
    let (m, k, n, e) = (m as u64, k as u64, n as u64, elt as u64);
    // read x (3 matmuls stream it), read Wg/Wu/Wd, write hg/hu/h/y
    3 * m * k * e + 3 * k * n * e + (3 * m * n + m * k) * e
}

/// Expected number of *unique* hidden columns touched when `nnz_total`
/// non-zeros land on `n` columns (coupon-collector expectation).  The
/// paper's kernels exploit exactly this: correlated activations across a
/// batch hit the same W_u/W_d rows, which stay L2-resident (section 3.3),
/// so DRAM is charged per unique column, not per non-zero.
pub fn unique_columns(n: usize, nnz_total: u64) -> u64 {
    let nf = n as f64;
    (nf * (1.0 - (-(nnz_total as f64) / nf).exp())).ceil() as u64
}

/// TwELL pipeline traffic: x once per kernel, Wg dense, W_u/W_d only the
/// *unique* touched rows/columns (L2 reuse), packed activations instead
/// of dense h.
pub fn ffn_twell_bytes(
    m: usize, k: usize, n: usize, comp: usize, nnz_total: u64, elt: usize,
) -> u64 {
    let (m, k, ne) = (m as u64, k as u64, n as u64);
    let e = elt as u64;
    let packed = m * (ne / comp as u64) * e + m * (ne / 32).max(1) * 4;
    let uniq = unique_columns(n, nnz_total);
    2 * m * k * e            // x read by both kernels
        + k * ne * e         // Wg
        + 2 * packed         // write + read TwELL
        + uniq * 2 * k * e   // wu col + wd row, once per unique column
        + m * k * e          // y write
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_monotone_in_all_terms() {
        let m = H100_PCIE;
        let base = m.joules(1_000_000, 1_000, 0.001);
        assert!(m.joules(2_000_000, 1_000, 0.001) > base);
        assert!(m.joules(1_000_000, 2_000, 0.001) > base);
        assert!(m.joules(1_000_000, 1_000, 0.002) > base);
    }

    #[test]
    fn sparse_traffic_below_dense_at_high_sparsity() {
        let (m, k, n) = (2048, 2048, 5632);
        let dense = ffn_dense_bytes(m, k, n, 2);
        let nnz = (m as u64) * 30; // paper's ~30 avg non-zeros
        let sparse = ffn_twell_bytes(m, k, n, 8, nnz, 2);
        assert!(sparse < dense, "{sparse} !< {dense}");
    }

    #[test]
    fn mj_per_token_scales_inverse_tokens() {
        let m = H100_PCIE;
        let a = m.mj_per_token(1 << 30, 1 << 20, 0.01, 1000);
        let b = m.mj_per_token(1 << 30, 1 << 20, 0.01, 2000);
        assert!((a / b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn h100_more_efficient_per_flop_than_rtx6000() {
        assert!(H100_PCIE.pj_per_flop < RTX6000.pj_per_flop);
    }
}
