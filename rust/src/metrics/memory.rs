//! Activation-memory accounting for the sparse formats (figure 1, the
//! Table 1 / figure 5 peak-memory columns, and appendix B.2.1 sizing).
//!
//! All sizes in bytes for a single (M x N) activation matrix; `elt` is
//! the element size (2 for bf16 on the paper's H100s, 4 for our f32 CPU
//! kernels — the *ratios* are element-size independent).

/// Dense storage: M*N elements.
pub fn dense_bytes(m: usize, n: usize, elt: usize) -> u64 {
    (m * n * elt) as u64
}

/// Classic ELL (section 3.1): padded to the global max nnz, plus an i16
/// column index per slot and a per-row count (ELLPACK-R).
pub fn ell_bytes(m: usize, max_nnz: usize, elt: usize) -> u64 {
    (m * max_nnz * (elt + 2) + m * 4) as u64
}

/// TwELL (section 3.2): values+indices packed at N/C per row + per-tile
/// counts.  The paper's packed 32-bit layout fuses value (bf16) and index
/// (16-bit) into one word and folds the count into the first slot; we
/// charge the same: N/C 32-bit words per row.
pub fn twell_bytes(m: usize, n: usize, comp: usize) -> u64 {
    (m * (n / comp) * 4) as u64
}

/// Hybrid training format (section 3.4): fixed-width ELL + i16 cols +
/// per-row count + route bit, plus the dense backup tail.
pub fn hybrid_bytes(
    m: usize, n: usize, ell_width: usize, dense_rows: usize, elt: usize,
) -> u64 {
    (m * ell_width * (elt + 2) + m * 5 + dense_rows * n * elt) as u64
}

/// Peak *activation* memory of a training step, per layer, dense vs
/// hybrid: dense keeps h_g, h_u, h (3 M*N matrices) for backward; the
/// hybrid path keeps one hybrid h_g + one hybrid h_u-like structure
/// (values only at the shared pattern) + the dense residual streams.
pub fn train_activations_dense(m: usize, n: usize, elt: usize) -> u64 {
    3 * dense_bytes(m, n, elt)
}

pub fn train_activations_hybrid(
    m: usize, n: usize, ell_width: usize, dense_rows: usize, elt: usize,
) -> u64 {
    2 * hybrid_bytes(m, n, ell_width, dense_rows, elt)
}

/// Simple peak tracker for measured allocations in the rust kernels.
#[derive(Default, Debug)]
pub struct PeakTracker {
    current: u64,
    pub peak: u64,
}

impl PeakTracker {
    pub fn alloc(&mut self, bytes: u64) {
        self.current += bytes;
        self.peak = self.peak.max(self.current);
    }

    pub fn free(&mut self, bytes: u64) {
        self.current = self.current.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twell_compression_ratio() {
        // comp=8 with bf16: paper stores N/8 32-bit words vs N bf16 =>
        // 4x smaller than dense
        let dense = dense_bytes(2048, 5632, 2);
        let tw = twell_bytes(2048, 5632, 8);
        assert!(tw * 3 < dense, "{tw} vs {dense}");
    }

    #[test]
    fn hybrid_much_smaller_than_dense_at_paper_sizing() {
        // appendix B.2.1: width 128, dense rows = M/8
        let m = 2048;
        let n = 5632;
        let dense = train_activations_dense(m, n, 2);
        let hybrid = train_activations_hybrid(m, n, 128, m / 8, 2);
        assert!(hybrid < dense / 2, "{hybrid} vs {dense}");
    }

    #[test]
    fn ell_grows_with_max_nnz() {
        assert!(ell_bytes(100, 64, 2) < ell_bytes(100, 640, 2));
    }

    #[test]
    fn peak_tracker_tracks_high_water() {
        let mut t = PeakTracker::default();
        t.alloc(100);
        t.alloc(50);
        t.free(120);
        t.alloc(10);
        assert_eq!(t.peak, 150);
    }
}
