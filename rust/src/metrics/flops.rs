//! FLOP accounting for feed-forward blocks and whole transformer steps.
//!
//! Conventions: one multiply-add = 2 FLOPs; sparse counts charge only the
//! touched non-zeros (the paper's "theoretical computation" axis that the
//! kernels try to realize in wall-clock).

/// Dense gated FFN forward FLOPs for a batch of `m` tokens (eq. 1):
/// gate + up projections (2*m*k*n each), elementwise (m*n), down (2*m*n*k).
pub fn ffn_gated_dense(m: usize, k: usize, n: usize) -> u64 {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    2 * m * k * n + 2 * m * k * n + m * n + 2 * m * n * k
}

/// Sparse gated FFN forward through the TwELL pipeline: the full gate
/// matmul is still dense (it *produces* the sparsity pattern), but up and
/// down only touch `nnz_total` hidden units (alg. 2 / eq. 3).
pub fn ffn_gated_twell(m: usize, k: usize, n: usize, nnz_total: u64) -> u64 {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    let gate = 2 * m * k * n;
    // per non-zero: dot(x, wu_col) = 2k, scale+axpy into y = 2k (+2)
    gate + nnz_total * (4 * k + 2) + _pack_overhead(m, n)
}

/// Non-gated FFN (eq. 5): dense up projection + sparse down.
pub fn ffn_nongated_twell(m: usize, k: usize, n: usize, nnz_total: u64) -> u64 {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    2 * m * k * n + nnz_total * (2 * k + 1) + _pack_overhead(m, n)
}

pub fn ffn_nongated_dense(m: usize, k: usize, n: usize) -> u64 {
    let (m, k, n) = (m as u64, k as u64, n as u64);
    2 * m * k * n + m * n + 2 * m * n * k
}

/// The epilogue pack is comparisons + counter bumps, charged as 2 ops per
/// element scanned.
fn _pack_overhead(m: u64, n: u64) -> u64 {
    2 * m * n
}

/// Attention FLOPs for one layer (projections + scores + mix).
pub fn attention(m: usize, s: usize, d: usize) -> u64 {
    let (m, s, d) = (m as u64, s as u64, d as u64);
    // q,k,v,o projections over m tokens + 2 * (m * s * d) score/mix
    8 * m * d * d + 4 * m * s * d
}

/// Full dense transformer forward for `m = batch*seq` tokens.
pub fn transformer_forward_dense(
    m: usize, s: usize, d: usize, f: usize, layers: usize, vocab: usize,
    gated: bool,
) -> u64 {
    let ffn = if gated {
        ffn_gated_dense(m, d, f)
    } else {
        ffn_nongated_dense(m, d, f)
    };
    let per_layer = attention(m, s, d) + ffn;
    per_layer * layers as u64 + 2 * (m as u64) * (d as u64) * (vocab as u64)
}

/// Training step ~= 3x forward (fwd + 2x bwd), the standard estimate.
pub fn transformer_train_dense(
    m: usize, s: usize, d: usize, f: usize, layers: usize, vocab: usize,
    gated: bool,
) -> u64 {
    3 * transformer_forward_dense(m, s, d, f, layers, vocab, gated)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_less_than_dense_when_sparse() {
        let (m, k, n) = (128, 256, 704);
        let dense = ffn_gated_dense(m, k, n);
        // 5% density
        let nnz = (m * n / 20) as u64;
        let sparse = ffn_gated_twell(m, k, n, nnz);
        assert!(sparse < dense, "{sparse} !< {dense}");
    }

    #[test]
    fn sparse_approaches_gate_cost_at_zero_nnz() {
        let (m, k, n) = (64, 128, 512);
        let sparse = ffn_gated_twell(m, k, n, 0);
        assert_eq!(sparse, 2 * (m * k * n) as u64 + 2 * (m * n) as u64);
    }

    #[test]
    fn fully_dense_twell_more_expensive_than_dense() {
        // at 100% density the sparse path does extra bookkeeping — the
        // paper's figure 10 observation (negative speedups for non-sparse
        // models)
        let (m, k, n) = (64, 128, 512);
        let nnz = (m * n) as u64;
        assert!(ffn_gated_twell(m, k, n, nnz) > ffn_gated_dense(m, k, n));
    }

    #[test]
    fn transformer_counts_scale_with_layers() {
        let f1 = transformer_forward_dense(256, 128, 128, 352, 2, 512, true);
        let f2 = transformer_forward_dense(256, 128, 128, 352, 4, 512, true);
        assert!(f2 > f1);
        assert!(f2 < 2 * f1); // lm head is shared
    }

    #[test]
    fn ffn_dominates_at_paper_ratios() {
        // paper section 1: FFN accounts for the majority of layer FLOPs
        // at d_ff = 8/3 d with gating
        let m = 2048;
        let (d, f) = (2048, 5632);
        let ffn = ffn_gated_dense(m, d, f);
        let attn = attention(m, 2048, d);
        assert!(ffn > attn);
    }
}
