//! `repro` — the L3 coordinator CLI.
//!
//! Subcommands (see DESIGN.md's per-experiment index):
//!   train     one training run (preset, l1, steps, mitigation, ...)
//!   sweep     experiment families: --what l1|scale|activation|gating|deadneuron
//!   eval      downstream task suite on a saved run    (figure 3 / tables 1,6)
//!   analyze   layer + token sparsity analysis of a run (figures 6/7/10/11)
//!   serve     demo serving loop on a saved run
//!   info      print platform + preset info

use anyhow::{bail, Context, Result};

use repro::config::{default_paths, Args, TrainConfig};
use repro::coordinator::{ckpt::Checkpoint, sweep, Trainer};
use repro::data::bpe::Bpe;
use repro::data::corpus::CorpusSpec;
use repro::model::{FfnBackend, Model};
use repro::runtime::{ModelBundle, Runtime, TrainState};
use repro::util::json::Json;

fn main() -> Result<()> {
    init_logger();
    let args = Args::parse(std::env::args().skip(1))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "eval" => cmd_eval(&args),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: repro <train|sweep|eval|analyze|serve|info> [flags]\n\
                 see DESIGN.md section 6 for the experiment index"
            );
            Ok(())
        }
    }
}

fn init_logger() {
    struct Stderr;
    impl log::Log for Stderr {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::Level::Info
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static STDERR: Stderr = Stderr;
    let _ = log::set_logger(&STDERR)
        .map(|_| log::set_max_level(log::LevelFilter::Info));
}

fn train_cfg_from(args: &Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::default();
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.peak_lr = args.get_f64("lr", cfg.peak_lr)?;
    cfg.warmup_steps = args.get_usize("warmup", cfg.steps / 10)?;
    cfg.l1_coeff = args.get_f64("l1", cfg.l1_coeff)?;
    cfg.seed = args.get_usize("seed", 0)? as u64;
    cfg.mitigation = args.get_or("mitigation", "none");
    cfg.l1_warmup_steps = args.get_usize("l1-warmup", cfg.steps / 4)?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let paths = default_paths();
    let preset = args.get_or("preset", "tiny");
    let cfg = train_cfg_from(args)?;
    let run_name = args.get_or(
        "name",
        &format!("train_{preset}_l1{:.0e}", cfg.l1_coeff),
    );
    let mut rt = Runtime::cpu()?;
    let mut tr = Trainer::new(&paths, &mut rt, &preset, cfg, &run_name)?;
    let res = tr.run(&CorpusSpec::default())?;
    println!(
        "run {run_name}: final ce {:.4}, mean nnz {:.1}, dead {:.1}%, \
         {:.0} tok/s, checkpoint at {:?}",
        res.final_ce(),
        repro::util::stats::mean(
            &res.final_nnz_per_layer.iter().map(|&v| v as f64)
                .collect::<Vec<_>>()
        ),
        res.final_dead_frac * 100.0,
        res.tokens_per_s,
        res.run_dir.join("checkpoint.bin"),
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let paths = default_paths();
    let what = args.get_or("what", "l1");
    let steps = args.get_usize("steps", 240)?;
    let mut rt = Runtime::cpu()?;
    // the paper grid, rescaled to our loss landscape (EXPERIMENTS.md)
    let grid = sweep::scaled_l1_grid(&[
        0.0, 5e-6, 1e-5, 1.5e-5, 2e-5, 3e-5, 5e-5, 1e-4,
    ]);
    let l1_rec = 2e-5 * sweep::L1_SCALE;
    let l1_aggr = 3e-5 * sweep::L1_SCALE;
    let outcome = match what.as_str() {
        "l1" => {
            let preset = args.get_or("preset", "s");
            sweep::sweep_l1(&paths, &mut rt, &preset, steps, &grid)?
        }
        "scale" => sweep::sweep_scale(
            &paths, &mut rt, &["xs", "s", "m", "l"], steps, l1_rec,
        )?,
        "activation" => {
            sweep::sweep_activation(&paths, &mut rt, steps, l1_rec)?
        }
        "gating" => {
            sweep::sweep_gating(&paths, &mut rt, steps, l1_rec, l1_aggr)?
        }
        "deadneuron" => {
            sweep::sweep_deadneuron(&paths, &mut rt, steps, l1_rec)?
        }
        other => bail!("unknown sweep {other:?}"),
    };
    let path = outcome.write(&paths)?;
    println!("sweep {what} complete -> {path:?}");
    Ok(())
}

fn load_run(run: &str) -> Result<(Model, Bpe)> {
    let paths = default_paths();
    let dir = paths.run_dir(run);
    let ck = Checkpoint::load(&dir.join("checkpoint.bin"))?;
    let model = Model::from_checkpoint(&ck, FfnBackend::Twell)?;
    let bpe = Bpe::from_json(&Json::read_file(&dir.join("tokenizer.json"))?)?;
    Ok((model, bpe))
}

fn cmd_eval(args: &Args) -> Result<()> {
    let run = args.require("run")?;
    let n = args.get_usize("n", 50)?;
    let (model, bpe) = load_run(run)?;
    let results = repro::eval::evaluate(&model, &bpe, n, 7)?;
    let mut table =
        repro::util::bench::Table::new(&["task", "accuracy", "n"]);
    for r in &results {
        table.row(&[
            r.task.clone(),
            format!("{:.1}%", r.accuracy * 100.0),
            r.n.to_string(),
        ]);
    }
    table.print();
    println!(
        "mean task accuracy: {:.1}%",
        repro::eval::mean_accuracy(&results) * 100.0
    );
    let paths = default_paths();
    Json::obj(vec![
        ("run", Json::str(run)),
        (
            "tasks",
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("task", Json::str(&r.task)),
                            ("accuracy", Json::Num(r.accuracy)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("mean_accuracy", Json::Num(repro::eval::mean_accuracy(&results))),
    ])
    .write_file(&paths.run_dir(run).join("eval.json"))?;
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    let run = args.require("run")?;
    let what = args.get_or("what", "layers");
    let paths = default_paths();
    let dir = paths.run_dir(run);
    let ck = Checkpoint::load(&dir.join("checkpoint.bin"))?;
    let preset = ck.config.name.clone();
    let bundle = ModelBundle::open(&paths.artifacts, &preset)?;
    let mut rt = Runtime::cpu()?;
    let params: Vec<Vec<f32>> =
        ck.params.iter().map(|(_, _, d)| d.clone()).collect();
    let state = TrainState::from_params(&bundle, &params)?;
    let bpe = Bpe::from_json(&Json::read_file(&dir.join("tokenizer.json"))?)?;
    match what.as_str() {
        "layers" => repro::analysis::analyze_layers(
            &bundle, &mut rt, &state, &ck, &dir,
        ),
        "tokens" => repro::analysis::analyze_tokens(
            &bundle, &mut rt, &state, &bpe, &dir,
        ),
        other => bail!("unknown analysis {other:?}"),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let run = args.require("run")?;
    let n_requests = args.get_usize("requests", 16)?.max(1);
    let max_new = args.get_usize("max-new", 16)?;
    // engine shards behind the one admission queue; each owns a full
    // slots/kv-blocks pool and one engine thread
    let shards = args.get_usize("shards", 1)?.max(1);
    // kernel worker-pool size (0 = auto: REPRO_THREADS or the core
    // count), interpreted as a TOTAL budget split across shards.  Set
    // before the first kernel call so the pool and every partition
    // decision see it.
    let threads = args.get_usize("threads", 0)?;
    if threads > 0 {
        repro::sparse::par::set_threads(
            repro::sparse::par::threads_per_shard(threads, shards),
        );
    } else if shards > 1 {
        let auto = repro::sparse::par::num_threads();
        repro::sparse::par::set_threads(
            repro::sparse::par::threads_per_shard(auto, shards),
        );
    }
    // scheduler tunables (continuous-batching engine, paged KV pool)
    let slots = args.get_usize("slots", 8)?;
    let max_wait_ms = args.get_f64("max-wait-ms", 5.0)?;
    let kv_block_size = args.get_usize("kv-block-size", 16)?;
    let kv_blocks = args.get_usize("kv-blocks", 256)?;
    // prompt tokens fed per prefilling slot per engine iteration;
    // defaults to one KV block (1 = legacy token-by-token prefill)
    let prefill_chunk =
        args.get_usize("prefill-chunk", kv_block_size)?;
    // union-density threshold for batch-contextual FFN routing on the
    // TwELL backend (0 disables the routed path entirely)
    let route_density = args.get_f64("route-density", 0.25)? as f32;
    // overload QoS: bound the admission queue (0 = unbounded, the
    // historical behaviour) and optionally give every request a
    // deadline measured from submit (0 = none)
    let max_queue = args.get_usize("max-queue", 0)?;
    let deadline_ms = args.get_f64("deadline-ms", 0.0)?;
    // per-request sampling: temperature 0 (the default) is greedy;
    // request i gets seed `--seed + i`, so the run is reproducible
    // while streams still diverge across requests
    let temperature = args.get_f64("temperature", 0.0)? as f32;
    let top_k = args.get_usize("top-k", 0)?;
    let top_p = args.get_f64("top-p", 1.0)? as f32;
    let seed = args.get_usize("seed", 0)? as u64;
    let base_params = repro::model::sample::SamplingParams {
        temperature,
        top_k,
        top_p,
        seed,
    };
    base_params.validate()?;
    let mode = match args.get_or("mode", "continuous").as_str() {
        "seq" | "sequential" => repro::serve::ServeMode::Sequential,
        "continuous" => repro::serve::ServeMode::Continuous,
        other => bail!("unknown serve mode {other:?}"),
    };
    // copy-on-write prefix caching across requests in the paged KV
    // pool; token streams are bit-identical either way, so this is a
    // pure memory/TTFT knob
    let prefix_cache = match args.get_or("prefix-cache", "on").as_str() {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => bail!("unknown --prefix-cache value {other:?}"),
    };
    let backend = match args.get_or("backend", "twell").as_str() {
        "dense" => FfnBackend::Dense,
        "twell" => FfnBackend::Twell,
        other => bail!("unknown backend {other:?}"),
    };
    let (mut model, bpe) = load_run(run)?;
    model.backend = backend;
    let policy = repro::serve::ServePolicy {
        slots,
        max_wait: std::time::Duration::from_secs_f64(max_wait_ms / 1e3),
        kv_block_size,
        kv_blocks,
        prefill_chunk,
        route_density,
        shards,
        prefix_cache,
        max_queue,
        mode,
    };
    let server = repro::serve::Server::start(model, policy);
    let mut metrics = repro::serve::ServeMetrics::default();
    let t0 = std::time::Instant::now();
    let prompts = [
        "topic geography : the river",
        "topic chemistry : the acid",
        "source : www nih",
        "the empire doesn",
    ];
    let params_for = |i: usize| repro::model::sample::SamplingParams {
        seed: seed.wrapping_add(i as u64),
        ..base_params
    };
    // fresh options per request: the deadline clock starts at submit
    let opts_for = || repro::serve::SubmitOptions {
        deadline: (deadline_ms > 0.0).then(|| {
            std::time::Instant::now()
                + std::time::Duration::from_secs_f64(deadline_ms / 1e3)
        }),
        max_queue_wait: None,
    };
    // stream the first request's tokens to show the per-token channel
    let (_, stream_rx, first_rx) = server
        .submit_streaming_opts(
            bpe.encode(prompts[0]),
            max_new,
            params_for(0),
            opts_for(),
        )
        .map_err(anyhow::Error::new)?;
    let rxs: Vec<_> = (1..n_requests)
        .map(|i| {
            let prompt = bpe.encode(prompts[i % prompts.len()]);
            server
                .submit_opts(prompt, max_new, params_for(i), opts_for())
                .map(|(_, rx)| rx)
                .map_err(anyhow::Error::new)
        })
        .collect::<Result<_>>()?;
    for t in stream_rx.iter() {
        eprint!("{}", bpe.decode(&[t.token]));
    }
    eprintln!();
    metrics.record(first_rx.recv().context("worker dropped")?);
    for rx in rxs {
        let c = rx.recv().context("worker dropped")?;
        println!(
            "req {} ({} prefill): {:?} [queue {:.1} ms, first token \
             {:.1} ms, total {:.1} ms, {:?}]",
            c.id,
            c.prefill_tokens,
            bpe.decode(&c.tokens),
            c.queue_ms,
            c.first_token_ms,
            c.total_ms,
            c.finish
        );
        metrics.record(c);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let sampling = if temperature == 0.0 {
        "greedy".to_string()
    } else {
        format!("t={temperature} top_k={top_k} top_p={top_p} seed={seed}")
    };
    println!(
        "served {n_requests} requests ({mode:?}, {shards} shards x \
         {slots} slots, {kv_blocks} KV blocks x {kv_block_size} \
         positions per shard, prefill chunk {prefill_chunk}, {} pool \
         threads/shard, {sampling}): p50 {:.1} ms, p95 {:.1} ms, p99 \
         {:.1} ms, ttft p50 {:.1} ms, {:.0} tok/s",
        repro::sparse::par::num_threads(),
        metrics.p50_ms(),
        metrics.p95_ms(),
        metrics.p99_ms(),
        metrics.p50_first_token_ms(),
        metrics.throughput_tok_s(wall)
    );
    for (i, st) in server.shard_stats().iter().enumerate() {
        println!(
            "shard {i}: {} admissions ({} backfilled), {} steps, \
             max active {}",
            st.admissions, st.backfilled, st.steps, st.max_active
        );
    }
    println!(
        "engine (merged): {} steps, {} prefill chunks, {} admissions \
         ({} backfilled), max active {}, queue peak {}, {} abandoned, \
         {} fallbacks",
        stats.steps,
        stats.prefill_chunks,
        stats.admissions,
        stats.backfilled,
        stats.max_active,
        stats.queue_peak,
        stats.abandoned,
        stats.fallbacks
    );
    let deadline_desc = if deadline_ms > 0.0 {
        format!("{deadline_ms} ms")
    } else {
        "none".to_string()
    };
    println!(
        "overload (max queue {max_queue}, deadline {deadline_desc}): \
         {} shed at deadline, {} deadline aborts, {} busy-shed, \
         {} queue rejections, {} shard restarts",
        stats.shed_deadline,
        stats.deadline_aborts,
        stats.shed_busy,
        stats.queue_rejections,
        stats.shard_restarts
    );
    println!(
        "ffn dispatch: {} routed, {} fallback, {} col-parallel, \
         {} row-parallel (mean union density {:.3})",
        stats.ffn_routed,
        stats.ffn_fallback,
        stats.ffn_col,
        stats.ffn_row,
        stats.mean_union_density()
    );
    println!(
        "prefix cache ({}): {} hits, {} blocks shared, {} cow copies, \
         peak {} KV blocks in use",
        if prefix_cache { "on" } else { "off" },
        stats.prefix_hits,
        stats.prefix_blocks_shared,
        stats.cow_copies,
        stats.kv_blocks_peak
    );
    server.shutdown();
    Ok(())
}

fn cmd_info() -> Result<()> {
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    let paths = default_paths();
    for preset in ["tiny", "xs", "s", "m", "l", "m-silu", "m-nongated"] {
        if let Ok(b) = ModelBundle::open(&paths.artifacts, preset) {
            println!(
                "preset {preset}: {} params, {} layers, d={} f={}",
                b.manifest.total_params(),
                b.manifest.config.n_layers,
                b.manifest.config.d_model,
                b.manifest.config.d_ff,
            );
        }
    }
    Ok(())
}
