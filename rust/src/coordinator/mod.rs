//! L3 coordinator: the training orchestrator that drives the AOT'd
//! train-step artifacts through PJRT, tracks sparsity / dead-neuron
//! statistics, applies the appendix C.3 mitigation strategies, logs every
//! run as JSON under `runs/`, and exports checkpoints the rust inference
//! engine (`model/`) can load.

pub mod ckpt;
pub mod deadneuron;
pub mod sweep;

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{Paths, TrainConfig};
use crate::data::corpus::CorpusSpec;
use crate::data::loader::{Dataset, Loader};
use crate::runtime::{ModelBundle, Runtime, StepStats, TrainState};
use crate::util::json::Json;

/// One logged training step (a row of figure 2 / 8 / 9 raw data).
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub ce: f32,
    pub mean_nnz: f32,
    pub dead_frac: f32,
    pub grad_norm: f32,
}

/// Result of a full training run.
pub struct RunResult {
    pub records: Vec<StepRecord>,
    pub final_nnz_per_layer: Vec<f32>,
    pub final_dead_frac: f32,
    pub wallclock_s: f64,
    pub tokens_per_s: f64,
    pub run_dir: PathBuf,
}

impl RunResult {
    pub fn final_ce(&self) -> f32 {
        // average of the last few records for stability
        let tail: Vec<f64> = self
            .records
            .iter()
            .rev()
            .take(5)
            .map(|r| r.ce as f64)
            .collect();
        crate::util::stats::mean(&tail) as f32
    }
}

/// Training orchestrator for one run.
pub struct Trainer<'rt> {
    pub bundle: ModelBundle,
    pub rt: &'rt mut Runtime,
    pub cfg: TrainConfig,
    pub run_name: String,
    pub paths: Paths,
}

impl<'rt> Trainer<'rt> {
    pub fn new(
        paths: &Paths, rt: &'rt mut Runtime, preset: &str, cfg: TrainConfig,
        run_name: &str,
    ) -> Result<Self> {
        let bundle = ModelBundle::open(&paths.artifacts, preset)
            .with_context(|| format!("preset {preset} (run `make artifacts`?)"))?;
        Ok(Trainer {
            bundle,
            rt,
            cfg,
            run_name: run_name.to_string(),
            paths: paths.clone(),
        })
    }

    /// Train on the synthetic corpus; returns the run summary and writes
    /// runs/<name>/{log.json, checkpoint.bin, tokenizer.json}.
    pub fn run(&mut self, corpus: &CorpusSpec) -> Result<RunResult> {
        let mcfg = self.bundle.manifest.config.clone();
        let (ds, bpe) = Dataset::synthetic(corpus, mcfg.vocab_size);
        anyhow::ensure!(
            ds.vocab_size <= mcfg.vocab_size,
            "tokenizer vocab {} exceeds model vocab {}",
            ds.vocab_size,
            mcfg.vocab_size
        );
        let mut loader =
            Loader::new(&ds, mcfg.train_batch, mcfg.seq_len, self.cfg.seed);
        let bundle = &self.bundle;
        let mut state = TrainState::init(bundle, self.rt,
                                         self.cfg.seed as i32)?;
        let mut tracker = deadneuron::Tracker::new(mcfg.n_layers, mcfg.d_ff);
        let mut records = Vec::new();
        let scan_k = self.bundle.manifest.scan_k;
        let t0 = Instant::now();
        let mut step = 0usize;
        let tokens_per_step = mcfg.train_batch * mcfg.seq_len;
        while step < self.cfg.steps {
            let use_scan = self.cfg.steps - step >= scan_k
                && self.cfg.mitigation != "reinit";
            let stats_list: Vec<StepStats> = if use_scan {
                let toks = loader.next_batches(scan_k);
                let lrs: Vec<f32> = (0..scan_k)
                    .map(|i| self.cfg.lr_at(step + i) as f32)
                    .collect();
                // l1 held constant within the window (warmup granularity
                // of scan_k steps)
                let l1 = self.cfg.l1_at(step) as f32;
                state.step_k(bundle, self.rt, &toks, &lrs, l1)?
            } else {
                let toks = loader.next_batch();
                let lr = self.cfg.lr_at(step) as f32;
                let l1 = self.cfg.l1_at(step) as f32;
                vec![state.step(bundle, self.rt, &toks, lr, l1)?]
            };
            for st in &stats_list {
                if !st.active.is_empty() {
                    tracker.observe(&st.active);
                }
                let mean_nnz = st.nnz.iter().sum::<f32>()
                    / st.nnz.len().max(1) as f32;
                records.push(StepRecord {
                    step,
                    loss: st.loss,
                    ce: st.ce,
                    mean_nnz,
                    dead_frac: tracker.dead_fraction(),
                    grad_norm: st.grad_norm,
                });
                if step % self.cfg.log_every == 0 {
                    log::info!(
                        "[{}] step {step}: loss {:.4} ce {:.4} nnz {:.1} dead {:.1}%",
                        self.run_name, st.loss, st.ce, mean_nnz,
                        tracker.dead_fraction() * 100.0
                    );
                }
                step += 1;
            }
            // appendix C.3: targeted reinit of dead gate columns after
            // each step (we apply it per observation window)
            if self.cfg.mitigation == "reinit" {
                let last = stats_list.last().unwrap();
                if !last.active.is_empty() {
                    state.reinit(
                        bundle,
                        self.rt,
                        &last.active,
                        step as i32,
                        self.cfg.reinit_lambda as f32,
                    )?;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();

        // final sparsity statistics from a held-out batch
        let toks = loader.next_batch();
        let lr = self.cfg.lr_at(self.cfg.steps.saturating_sub(1)) as f32;
        let final_stats =
            state.step(bundle, self.rt, &toks, lr * 0.0,
                       self.cfg.l1_coeff as f32)?;

        let run_dir = self.paths.run_dir(&self.run_name);
        std::fs::create_dir_all(&run_dir)?;
        self.write_log(&run_dir, &records, &final_stats, &tracker)?;
        ckpt::save(
            &run_dir.join("checkpoint.bin"),
            &self.bundle.manifest,
            &state.params_f32()?,
        )?;
        bpe.to_json().write_file(&run_dir.join("tokenizer.json"))?;

        Ok(RunResult {
            records,
            final_nnz_per_layer: final_stats.nnz,
            final_dead_frac: tracker.dead_fraction(),
            wallclock_s: wall,
            tokens_per_s: (self.cfg.steps * tokens_per_step) as f64 / wall,
            run_dir,
        })
    }

    fn write_log(
        &self, dir: &std::path::Path, records: &[StepRecord],
        final_stats: &StepStats, tracker: &deadneuron::Tracker,
    ) -> Result<()> {
        let j = Json::obj(vec![
            ("run", Json::str(&self.run_name)),
            ("preset", Json::str(&self.bundle.manifest.preset)),
            ("l1_coeff", Json::Num(self.cfg.l1_coeff)),
            ("steps", Json::Num(self.cfg.steps as f64)),
            ("seed", Json::Num(self.cfg.seed as f64)),
            ("mitigation", Json::str(&self.cfg.mitigation)),
            (
                "step",
                Json::arr_usize(
                    &records.iter().map(|r| r.step).collect::<Vec<_>>(),
                ),
            ),
            (
                "loss",
                Json::arr_f32(
                    &records.iter().map(|r| r.loss).collect::<Vec<_>>(),
                ),
            ),
            (
                "ce",
                Json::arr_f32(&records.iter().map(|r| r.ce).collect::<Vec<_>>()),
            ),
            (
                "mean_nnz",
                Json::arr_f32(
                    &records.iter().map(|r| r.mean_nnz).collect::<Vec<_>>(),
                ),
            ),
            (
                "dead_frac",
                Json::arr_f32(
                    &records.iter().map(|r| r.dead_frac).collect::<Vec<_>>(),
                ),
            ),
            ("final_nnz_per_layer", Json::arr_f32(&final_stats.nnz)),
            ("final_dead_frac", Json::Num(tracker.dead_fraction() as f64)),
        ]);
        j.write_file(&dir.join("log.json"))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_record_final_ce_averages_tail() {
        let records: Vec<StepRecord> = (0..10)
            .map(|i| StepRecord {
                step: i,
                loss: 1.0,
                ce: i as f32,
                mean_nnz: 0.0,
                dead_frac: 0.0,
                grad_norm: 0.0,
            })
            .collect();
        let r = RunResult {
            records,
            final_nnz_per_layer: vec![],
            final_dead_frac: 0.0,
            wallclock_s: 1.0,
            tokens_per_s: 0.0,
            run_dir: PathBuf::from("."),
        };
        assert_eq!(r.final_ce(), 7.0); // mean of 5..=9
    }
}
