//! Sweep drivers — one per experiment family in DESIGN.md's index.
//!
//! Each sweep trains a set of configurations, writes the per-run logs
//! (figure 2/8/9 raw data) plus a `runs/sweep_<what>.json` summary that
//! `repro report` and EXPERIMENTS.md consume.

use anyhow::Result;

use crate::config::{Paths, TrainConfig};
use crate::coordinator::Trainer;
use crate::data::corpus::CorpusSpec;
use crate::runtime::Runtime;
use crate::util::json::Json;

/// Scaled L1 grid: the paper's 0..1e-4 grid maps onto our loss landscape
/// at a ~3e4x scale (recorded in EXPERIMENTS.md as `l1_scale`).  The
/// relative spacing of the paper's grid is preserved.
pub const L1_SCALE: f64 = 3.0e4;

pub fn scaled_l1_grid(paper_grid: &[f64]) -> Vec<f64> {
    paper_grid.iter().map(|v| v * L1_SCALE).collect()
}

pub struct SweepOutcome {
    pub name: String,
    pub summaries: Vec<Json>,
}

impl SweepOutcome {
    pub fn write(&self, paths: &Paths) -> Result<std::path::PathBuf> {
        let path = paths.runs.join(format!("sweep_{}.json", self.name));
        Json::obj(vec![
            ("sweep", Json::str(&self.name)),
            ("runs", Json::Arr(self.summaries.clone())),
        ])
        .write_file(&path)?;
        Ok(path)
    }
}

fn summarize(
    run_name: &str, preset: &str, l1: f64,
    res: &crate::coordinator::RunResult,
) -> Json {
    let mean_nnz = crate::util::stats::mean(
        &res.final_nnz_per_layer.iter().map(|&v| v as f64).collect::<Vec<_>>(),
    );
    Json::obj(vec![
        ("run", Json::str(run_name)),
        ("preset", Json::str(preset)),
        ("l1_coeff", Json::Num(l1)),
        ("final_ce", Json::Num(res.final_ce() as f64)),
        ("final_mean_nnz", Json::Num(mean_nnz)),
        ("final_nnz_per_layer", Json::arr_f32(&res.final_nnz_per_layer)),
        ("final_dead_frac", Json::Num(res.final_dead_frac as f64)),
        ("tokens_per_s", Json::Num(res.tokens_per_s)),
        ("wallclock_s", Json::Num(res.wallclock_s)),
        (
            "checkpoint",
            Json::str(&res.run_dir.join("checkpoint.bin").to_string_lossy()),
        ),
    ])
}

fn train_one(
    paths: &Paths, rt: &mut Runtime, preset: &str, cfg: TrainConfig,
    run_name: &str, corpus: &CorpusSpec,
) -> Result<Json> {
    let l1 = cfg.l1_coeff;
    let mut tr = Trainer::new(paths, rt, preset, cfg, run_name)?;
    let res = tr.run(corpus)?;
    log::info!(
        "run {run_name}: ce {:.4}, nnz {:.1}, {:.0} tok/s",
        res.final_ce(),
        crate::util::stats::mean(
            &res.final_nnz_per_layer.iter().map(|&v| v as f64)
                .collect::<Vec<_>>()
        ),
        res.tokens_per_s
    );
    Ok(summarize(run_name, preset, l1, &res))
}

/// EXP-F2/F3/F4/F5: train the sweep preset across the (scaled) paper L1
/// grid.
pub fn sweep_l1(
    paths: &Paths, rt: &mut Runtime, preset: &str, steps: usize,
    grid: &[f64],
) -> Result<SweepOutcome> {
    let corpus = CorpusSpec::default();
    let mut summaries = Vec::new();
    for &l1 in grid {
        let cfg = TrainConfig { steps, l1_coeff: l1, ..TrainConfig::default() };
        let run_name = format!("l1_{l1:.0e}");
        summaries.push(train_one(paths, rt, preset, cfg, &run_name, &corpus)?);
    }
    Ok(SweepOutcome { name: "l1".into(), summaries })
}

/// EXP-T1/T6: scale sweep — each preset trained dense (l1=0) and sparse
/// (recommended coefficient).
pub fn sweep_scale(
    paths: &Paths, rt: &mut Runtime, presets: &[&str], steps: usize,
    l1_rec: f64,
) -> Result<SweepOutcome> {
    let corpus = CorpusSpec::default();
    let mut summaries = Vec::new();
    for preset in presets {
        for (tag, l1) in [("dense", 0.0), ("sparse", l1_rec)] {
            let cfg =
                TrainConfig { steps, l1_coeff: l1, ..TrainConfig::default() };
            let run_name = format!("scale_{preset}_{tag}");
            summaries.push(train_one(paths, rt, preset, cfg, &run_name,
                                     &corpus)?);
        }
    }
    Ok(SweepOutcome { name: "scale".into(), summaries })
}

/// EXP-T3: ReLU vs SiLU on the analysis preset.
pub fn sweep_activation(
    paths: &Paths, rt: &mut Runtime, steps: usize, l1_rec: f64,
) -> Result<SweepOutcome> {
    let corpus = CorpusSpec::default();
    let runs: [(&str, &str, f64); 3] = [
        ("m", "act_relu_dense", 0.0),
        ("m-silu", "act_silu_dense", 0.0),
        ("m", "act_relu_sparse", l1_rec),
    ];
    let mut summaries = Vec::new();
    for (preset, run_name, l1) in runs {
        let cfg = TrainConfig { steps, l1_coeff: l1, ..TrainConfig::default() };
        summaries.push(train_one(paths, rt, preset, cfg, run_name, &corpus)?);
    }
    Ok(SweepOutcome { name: "activation".into(), summaries })
}

/// EXP-T4: gated vs non-gated at 3 sparsity levels each.
pub fn sweep_gating(
    paths: &Paths, rt: &mut Runtime, steps: usize, l1_rec: f64,
    l1_aggr: f64,
) -> Result<SweepOutcome> {
    let corpus = CorpusSpec::default();
    let mut summaries = Vec::new();
    for preset in ["m", "m-nongated"] {
        for (tag, l1) in
            [("l1_0", 0.0), ("l1_rec", l1_rec), ("l1_aggr", l1_aggr)]
        {
            let cfg =
                TrainConfig { steps, l1_coeff: l1, ..TrainConfig::default() };
            let run_name = format!("gating_{preset}_{tag}");
            summaries.push(train_one(paths, rt, preset, cfg, &run_name,
                                     &corpus)?);
        }
    }
    Ok(SweepOutcome { name: "gating".into(), summaries })
}

/// EXP-T5/F8: dead-neuron mitigation strategies (appendix C.3).
pub fn sweep_deadneuron(
    paths: &Paths, rt: &mut Runtime, steps: usize, l1_rec: f64,
) -> Result<SweepOutcome> {
    let corpus = CorpusSpec::default();
    let configs: [(&str, TrainConfig); 3] = [
        (
            "dn_baseline",
            TrainConfig { steps, l1_coeff: l1_rec, ..TrainConfig::default() },
        ),
        (
            "dn_reinit",
            TrainConfig {
                steps,
                l1_coeff: l1_rec,
                mitigation: "reinit".into(),
                ..TrainConfig::default()
            },
        ),
        (
            "dn_warmup",
            TrainConfig {
                steps,
                // the paper's warmup run uses 10x the recommended coeff
                l1_coeff: l1_rec * 10.0,
                mitigation: "warmup".into(),
                l1_warmup_steps: steps / 4,
                ..TrainConfig::default()
            },
        ),
    ];
    let mut summaries = Vec::new();
    for (run_name, cfg) in configs {
        summaries.push(train_one(paths, rt, "m", cfg, run_name, &corpus)?);
    }
    Ok(SweepOutcome { name: "deadneuron".into(), summaries })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_grid_preserves_ratios() {
        let grid = [0.0, 1e-5, 2e-5];
        let s = scaled_l1_grid(&grid);
        assert_eq!(s[0], 0.0);
        assert!((s[2] / s[1] - 2.0).abs() < 1e-12);
    }
}
