//! Checkpoint format: a JSON header (param names/shapes + model config)
//! followed by raw little-endian f32 data.  Written by the coordinator,
//! loaded by the rust inference engine (`model/`).
//!
//! Layout: `SPRSLITE` magic, u64 header length, header JSON, then each
//! parameter's data in manifest order.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::Manifest;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"SPRSLITE";

pub fn save(path: &Path, manifest: &Manifest, params: &[Vec<f32>])
    -> Result<()> {
    anyhow::ensure!(params.len() == manifest.params.len());
    let header = Json::obj(vec![
        ("preset", Json::str(&manifest.preset)),
        (
            "params",
            Json::Arr(
                manifest
                    .params
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("name", Json::str(&p.name)),
                            ("shape", Json::arr_usize(&p.shape)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "config",
            Json::parse(&config_json(manifest))
                .expect("config json"),
        ),
    ]);
    let header_bytes = header.to_string().into_bytes();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(header_bytes.len() as u64).to_le_bytes())?;
    f.write_all(&header_bytes)?;
    for (p, spec) in params.iter().zip(&manifest.params) {
        let n: usize = spec.shape.iter().product();
        anyhow::ensure!(p.len() == n, "{}: {} != {}", spec.name, p.len(), n);
        for v in p {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

fn config_json(manifest: &Manifest) -> String {
    let c = &manifest.config;
    format!(
        concat!(
            "{{\"name\":\"{}\",\"vocab_size\":{},\"d_model\":{},",
            "\"n_layers\":{},\"n_heads\":{},\"d_ff\":{},\"gated\":{},",
            "\"activation\":\"{}\",\"rope_theta\":{},\"rmsnorm_eps\":{},",
            "\"init_std\":{},\"train_batch\":{},\"seq_len\":{},",
            "\"score_batch\":{},\"twell_tile_n\":{},\"twell_comp\":{},",
            "\"ell_width\":{},\"dense_backup_frac\":{}}}"
        ),
        c.name, c.vocab_size, c.d_model, c.n_layers, c.n_heads, c.d_ff,
        c.gated, c.activation, c.rope_theta, c.rmsnorm_eps, c.init_std,
        c.train_batch, c.seq_len, c.score_batch, c.twell_tile_n,
        c.twell_comp, c.ell_width, c.dense_backup_frac,
    )
}

pub struct Checkpoint {
    pub header: Json,
    pub config: crate::config::ModelConfig,
    /// name -> flat data
    pub params: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("{path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a SPRSLITE checkpoint: {path:?}");
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        let mut hbytes = vec![0u8; hlen];
        f.read_exact(&mut hbytes)?;
        let header = Json::parse(std::str::from_utf8(&hbytes)?)?;
        let config =
            crate::config::ModelConfig::from_json(header.get("config")?)?;
        let mut params = Vec::new();
        for spec in header.get("params")?.as_arr()? {
            let name = spec.get("name")?.as_str()?.to_string();
            let shape = spec.get("shape")?.usize_vec()?;
            let n: usize = shape.iter().product();
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)
                .with_context(|| format!("reading {name}"))?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            params.push((name, shape, data));
        }
        Ok(Checkpoint { header, config, params })
    }

    pub fn get(&self, name: &str) -> Result<(&[usize], &[f32])> {
        self.params
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, s, d)| (s.as_slice(), d.as_slice()))
            .ok_or_else(|| anyhow::anyhow!("param {name:?} not in checkpoint"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn tiny_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
            "preset": "t",
            "config": {"name":"t","vocab_size":8,"d_model":4,"n_layers":1,
                "n_heads":1,"d_ff":8,"gated":true,"activation":"relu",
                "rope_theta":10000.0,"rmsnorm_eps":1e-05,"init_std":0.02,
                "train_batch":2,"seq_len":4,"score_batch":2,
                "twell_tile_n":4,"twell_comp":1,"ell_width":8,
                "dense_backup_frac":0.125},
            "scan_k": 8, "l1_grid": [0.0],
            "params": [{"name":"embed","shape":[8,4]},
                       {"name":"ln_final","shape":[4]}],
            "artifacts": {}
        }"#,
        )
        .unwrap();
        Manifest::from_json(&j).unwrap()
    }

    #[test]
    fn save_load_roundtrip() {
        let man = tiny_manifest();
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        let path = dir.join("c.bin");
        let p0: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let p1: Vec<f32> = vec![1.0; 4];
        save(&path, &man, &[p0.clone(), p1.clone()]).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.config.vocab_size, 8);
        let (shape, data) = ck.get("embed").unwrap();
        assert_eq!(shape, &[8, 4]);
        assert_eq!(data, p0.as_slice());
        let (_, d1) = ck.get("ln_final").unwrap();
        assert_eq!(d1, p1.as_slice());
        assert!(ck.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("repro_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC........").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shape_mismatch_rejected_on_save() {
        let man = tiny_manifest();
        let dir = std::env::temp_dir().join("repro_ckpt_mismatch");
        let path = dir.join("c.bin");
        let bad = vec![vec![0f32; 3], vec![0f32; 4]];
        assert!(save(&path, &man, &bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
