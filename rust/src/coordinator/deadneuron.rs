//! Dead-neuron tracking (paper appendix C.3 / D.1).
//!
//! A neuron is "dead for a step" when it produced zero activations over
//! the whole step's batch (~the paper's 1M-token window; ours is the
//! step batch or scan-window).  The tracker keeps per-neuron streaks and
//! reports the fraction that has been inactive for at least
//! `streak_threshold` consecutive observations, which converges to the
//! paper's "permanently inactive" notion as training settles (figure 9).

pub struct Tracker {
    layers: usize,
    width: usize,
    /// consecutive inactive observations per (layer, neuron)
    streak: Vec<u32>,
    observations: u32,
    pub streak_threshold: u32,
}

impl Tracker {
    pub fn new(layers: usize, width: usize) -> Self {
        Tracker {
            layers,
            width,
            streak: vec![0; layers * width],
            observations: 0,
            streak_threshold: 3,
        }
    }

    /// `active` is the flattened [layers * width] activation-count tensor
    /// returned by the train step (counts over the batch window).
    pub fn observe(&mut self, active: &[f32]) {
        assert_eq!(active.len(), self.streak.len());
        self.observations += 1;
        for (s, &a) in self.streak.iter_mut().zip(active) {
            if a == 0.0 {
                *s += 1;
            } else {
                *s = 0;
            }
        }
    }

    /// Fraction of neurons currently dead (streak >= threshold).
    pub fn dead_fraction(&self) -> f32 {
        if self.observations < self.streak_threshold {
            return 0.0;
        }
        let dead = self
            .streak
            .iter()
            .filter(|&&s| s >= self.streak_threshold)
            .count();
        dead as f32 / self.streak.len() as f32
    }

    /// Per-layer dead fractions (figure 9 per-layer breakdown).
    pub fn dead_fraction_per_layer(&self) -> Vec<f32> {
        (0..self.layers)
            .map(|l| {
                let row = &self.streak[l * self.width..(l + 1) * self.width];
                row.iter().filter(|&&s| s >= self.streak_threshold).count()
                    as f32
                    / self.width as f32
            })
            .collect()
    }

    /// Binary activity mask (1 = alive this window) for the reinit
    /// artifact: dead columns get 0.
    pub fn alive_mask(&self) -> Vec<f32> {
        self.streak
            .iter()
            .map(|&s| if s >= self.streak_threshold { 0.0 } else { 1.0 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_tracker_reports_zero() {
        let t = Tracker::new(2, 4);
        assert_eq!(t.dead_fraction(), 0.0);
    }

    #[test]
    fn persistent_zeros_become_dead() {
        let mut t = Tracker::new(1, 4);
        let obs = vec![0.0, 1.0, 0.0, 2.0];
        for _ in 0..3 {
            t.observe(&obs);
        }
        assert_eq!(t.dead_fraction(), 0.5);
        assert_eq!(t.dead_fraction_per_layer(), vec![0.5]);
        assert_eq!(t.alive_mask(), vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn revival_resets_streak() {
        let mut t = Tracker::new(1, 2);
        for _ in 0..3 {
            t.observe(&[0.0, 0.0]);
        }
        assert_eq!(t.dead_fraction(), 1.0);
        t.observe(&[5.0, 0.0]); // neuron 0 revives
        assert_eq!(t.dead_fraction(), 0.5);
    }

    #[test]
    fn needs_threshold_observations() {
        let mut t = Tracker::new(1, 2);
        t.observe(&[0.0, 0.0]);
        assert_eq!(t.dead_fraction(), 0.0); // too early to call anything dead
    }
}
