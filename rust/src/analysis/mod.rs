//! Analysis drivers for the paper's section 4.3 (figures 6/7/10/11):
//! per-layer sparsity statistics + speedup attribution, and token/position
//! sparsity profiles.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Result;

use crate::coordinator::ckpt::Checkpoint;
use crate::data::bpe::Bpe;
use crate::data::corpus::CorpusSpec;
use crate::data::loader::{Dataset, Loader};
use crate::model::{FfnBackend, Model};
use crate::runtime::{ModelBundle, Runtime, TrainState};
use crate::util::json::Json;
use crate::util::stats;

/// How many analysis tokens to stream (the paper uses 2^20; scaled here).
const ANALYSIS_TOKENS: usize = 1 << 15;

/// Figure 6 / 10 / 11: per-layer mean+max nnz, per-layer FFN speedup of
/// the TwELL backend over dense on *real* activations, and the Pearson
/// correlation between mean nnz and speedup.
pub fn analyze_layers(
    bundle: &ModelBundle, rt: &mut Runtime, state: &TrainState,
    ck: &Checkpoint, out_dir: &Path,
) -> Result<()> {
    let cfg = &bundle.manifest.config;
    let layers = cfg.n_layers;
    // --- nnz statistics via the PJRT forward_stats artifact ------------
    let spec = CorpusSpec { seed: 77, ..CorpusSpec::default() };
    let (ds, _bpe) = Dataset::synthetic(&spec, cfg.vocab_size);
    let mut loader = Loader::new(&ds, cfg.score_batch, cfg.seq_len, 7);
    let per_batch = cfg.score_batch * cfg.seq_len;
    let n_batches = (ANALYSIS_TOKENS / per_batch).max(1);
    let mut mean_nnz = vec![0f64; layers];
    let mut max_nnz = vec![0f64; layers];
    for _ in 0..n_batches {
        let toks: Vec<i32> = loader
            .next_batch()
            .into_iter()
            .take(per_batch)
            .collect();
        let stats_flat = state.forward_stats(bundle, rt, &toks)?;
        for l in 0..layers {
            let sl = &stats_flat[l * per_batch..(l + 1) * per_batch];
            mean_nnz[l] +=
                sl.iter().map(|&v| v as f64).sum::<f64>() / per_batch as f64;
            max_nnz[l] = max_nnz[l]
                .max(sl.iter().cloned().fold(0f32, f32::max) as f64);
        }
    }
    for v in mean_nnz.iter_mut() {
        *v /= n_batches as f64;
    }

    // --- per-layer speedups on real activations -------------------------
    let model_d = Model::from_checkpoint(ck, FfnBackend::Dense)?;
    let model_s = Model::from_checkpoint(ck, FfnBackend::Twell)?;
    let toks: Vec<u32> = loader
        .next_batch()
        .into_iter()
        .take(per_batch)
        .map(|t| t as u32)
        .collect();
    // warm-up + repeat for stable timing
    let mut dense_s = vec![0f64; layers];
    let mut sparse_s = vec![0f64; layers];
    for rep in 0..4 {
        let (_, sd) = model_d.forward(&toks, cfg.score_batch, cfg.seq_len);
        let (_, ss) = model_s.forward(&toks, cfg.score_batch, cfg.seq_len);
        if rep == 0 {
            continue; // warm-up
        }
        for l in 0..layers {
            dense_s[l] += sd.ffn_seconds[l];
            sparse_s[l] += ss.ffn_seconds[l];
        }
    }
    let speedup: Vec<f64> = dense_s
        .iter()
        .zip(&sparse_s)
        .map(|(d, &s)| d / s.max(1e-12))
        .collect();
    let pearson = stats::pearson(&mean_nnz, &speedup);

    let mut table = crate::util::bench::Table::new(&[
        "layer", "mean nnz", "max nnz", "ffn speedup",
    ]);
    for l in 0..layers {
        table.row(&[
            l.to_string(),
            format!("{:.1}", mean_nnz[l]),
            format!("{:.0}", max_nnz[l]),
            format!("{:.2}x", speedup[l]),
        ]);
    }
    table.print();
    println!("pearson(mean nnz, speedup) = {pearson:.4}");

    Json::obj(vec![
        ("mean_nnz", Json::arr_f64(&mean_nnz)),
        ("max_nnz", Json::arr_f64(&max_nnz)),
        ("ffn_speedup", Json::arr_f64(&speedup)),
        ("pearson", Json::Num(pearson)),
        ("analysis_tokens", Json::Num((n_batches * per_batch) as f64)),
    ])
    .write_file(&out_dir.join("analysis_layers.json"))?;
    Ok(())
}

/// Figure 7: token-identity and position sparsity profiles.
pub fn analyze_tokens(
    bundle: &ModelBundle, rt: &mut Runtime, state: &TrainState, bpe: &Bpe,
    out_dir: &Path,
) -> Result<()> {
    let cfg = &bundle.manifest.config;
    let layers = cfg.n_layers;
    let spec = CorpusSpec { seed: 77, ..CorpusSpec::default() };
    let (ds, _) = Dataset::synthetic(&spec, cfg.vocab_size);
    let mut loader = Loader::new(&ds, cfg.score_batch, cfg.seq_len, 13);
    let per_batch = cfg.score_batch * cfg.seq_len;
    let n_batches = (ANALYSIS_TOKENS / per_batch).max(1);

    let mut tok_sum: HashMap<u32, (f64, u64)> = HashMap::new();
    let mut pos_sum = vec![0f64; cfg.seq_len];
    let mut pos_count = vec![0u64; cfg.seq_len];
    let mut total_tokens = 0u64;
    for _ in 0..n_batches {
        let toks = loader.next_batch();
        let input: Vec<i32> = toks.iter().take(per_batch).cloned().collect();
        let stats_flat = state.forward_stats(bundle, rt, &input)?;
        for b in 0..cfg.score_batch {
            for s in 0..cfg.seq_len {
                let idx = b * cfg.seq_len + s;
                // mean over layers = the paper's per-token nnz statistic
                let mut nnz = 0f64;
                for l in 0..layers {
                    nnz += stats_flat[l * per_batch + idx] as f64;
                }
                nnz /= layers as f64;
                let t = input[idx] as u32;
                let e = tok_sum.entry(t).or_insert((0.0, 0));
                e.0 += nnz;
                e.1 += 1;
                pos_sum[s] += nnz;
                pos_count[s] += 1;
                total_tokens += 1;
            }
        }
    }
    // frequency filter (paper: drop tokens rarer than 1/2^14)
    let min_count = (total_tokens / (1 << 10)).max(4);
    let mut per_token: Vec<(u32, f64, u64)> = tok_sum
        .into_iter()
        .filter(|(_, (_, c))| *c >= min_count)
        .map(|(t, (s, c))| (t, s / c as f64, c))
        .collect();
    per_token.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    println!("lowest-nnz tokens (boilerplate / contractions expected):");
    for (t, nnz, c) in per_token.iter().take(6) {
        println!("  {:>12?}  nnz {:.1}  (count {c})", bpe.token_str(*t), nnz);
    }
    println!("highest-nnz tokens (content words expected):");
    for (t, nnz, c) in per_token.iter().rev().take(6) {
        println!("  {:>12?}  nnz {:.1}  (count {c})", bpe.token_str(*t), nnz);
    }

    // position profile + log-log slope (figure 7b)
    let pos_mean: Vec<f64> = pos_sum
        .iter()
        .zip(&pos_count)
        .map(|(s, &c)| s / c.max(1) as f64)
        .collect();
    let xs: Vec<f64> =
        (1..=pos_mean.len()).map(|p| (p as f64).ln()).collect();
    let ys: Vec<f64> = pos_mean.iter().map(|&v| v.max(1e-9).ln()).collect();
    let (slope, _) = stats::linfit(&xs, &ys);
    println!(
        "position profile: nnz[0] = {:.1}, nnz[last] = {:.1}, \
         log-log slope = {slope:.3}",
        pos_mean[0],
        pos_mean[pos_mean.len() - 1]
    );

    Json::obj(vec![
        (
            "tokens",
            Json::Arr(
                per_token
                    .iter()
                    .map(|(t, nnz, c)| {
                        Json::obj(vec![
                            ("token", Json::str(&bpe.token_str(*t))),
                            ("nnz", Json::Num(*nnz)),
                            ("count", Json::Num(*c as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("position_mean_nnz", Json::arr_f64(&pos_mean)),
        ("loglog_slope", Json::Num(slope)),
    ])
    .write_file(&out_dir.join("analysis_tokens.json"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn analysis_token_budget_reasonable() {
        // paper uses 2^20; our scaled budget must still cover many batches
        assert!(super::ANALYSIS_TOKENS >= 1 << 14);
    }
}
