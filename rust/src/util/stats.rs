//! Statistics helpers used by the analysis drivers and benches
//! (mean/max/percentiles, Pearson correlation for figure 6, simple
//! histogramming for figure 7).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Largest value; 0 for empty input — `mean`/`max`/`min`/`percentile`
/// all share the 0-for-empty contract so report code can call them
/// unguarded (the old ±infinity answers leaked into JSON, which has no
/// encoding for them).
pub fn max(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Smallest value; 0 for empty input (see `max`).
pub fn min(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    mean(&xs.iter().map(|x| (x - m) * (x - m)).collect::<Vec<_>>())
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy (p in
/// [0,100]); 0 for empty input.  Sorts by IEEE total order, so a NaN
/// in the data lands at the end instead of panicking the comparator.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Pearson correlation coefficient (figure 6 reports r < -0.996 between
/// per-layer mean nnz and per-layer speedup).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Least-squares slope+intercept of y on x (used for the log-log position
/// decay fit in figure 7b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (slope, my - slope * mx)
}

/// Fixed-width histogram over [lo, hi); values outside are clamped.
/// A degenerate range (`hi <= lo`) has zero bin width — there is no
/// meaningful binning, so the histogram is all zeros rather than
/// letting the NaN/inf division silently dump every value into bin 0.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if bins == 0 || hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1);
        h[b as usize] += 1;
    }
    h
}

/// Element-wise sum of two equal-shape histograms — the shard-merge
/// operation: because `histogram` is a pure per-sample bin count,
/// merging two shards' histograms is identical to histogramming the
/// concatenation of their samples, and merging with an all-zero
/// (empty-shard) histogram is the identity.  The same contract backs
/// `serve::EngineStats::merge`'s latency histogram.
pub fn merge_histograms(a: &[usize], b: &[usize]) -> Vec<usize> {
    assert_eq!(a.len(), b.len(), "histogram shapes must match");
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(max(&xs), 4.0);
        assert_eq!(min(&xs), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
    }

    #[test]
    fn linfit_exact() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [1.0, 3.0, 5.0];
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.9, 1.5, -3.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![3, 2]); // -3 clamps to bin 0, 1.5 to bin 1
    }

    #[test]
    fn empty_inputs_share_the_zero_contract() {
        // max/min used to answer -inf/+inf on empty input and
        // percentile asserted; all now match mean's 0-for-empty
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(max(&[]), 0.0);
        assert_eq!(min(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn percentile_survives_nan() {
        // partial_cmp().unwrap() used to panic on NaN; total_cmp
        // sorts NaN after every finite value instead
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn merged_histograms_equal_histogram_of_concatenated_samples() {
        // the shard-merge identity: per-shard binning then summing ==
        // binning the pooled samples
        let xs = [0.1, 0.4, 0.9, 2.5, -1.0];
        let ys = [0.6, 0.6, 1.2, 0.05];
        let all: Vec<f64> =
            xs.iter().chain(&ys).copied().collect();
        let (lo, hi, bins) = (0.0, 1.0, 4);
        assert_eq!(
            merge_histograms(
                &histogram(&xs, lo, hi, bins),
                &histogram(&ys, lo, hi, bins),
            ),
            histogram(&all, lo, hi, bins)
        );
    }

    #[test]
    fn merging_an_empty_shard_histogram_is_identity() {
        let xs = [0.2, 0.7, 3.0];
        let h = histogram(&xs, 0.0, 1.0, 5);
        let empty = histogram(&[], 0.0, 1.0, 5);
        assert_eq!(empty, vec![0; 5]);
        assert_eq!(merge_histograms(&h, &empty), h);
        assert_eq!(merge_histograms(&empty, &h), h);
        // degenerate meta-case: merging two empty shards
        assert_eq!(merge_histograms(&empty, &empty), vec![0; 5]);
    }

    #[test]
    #[should_panic(expected = "histogram shapes must match")]
    fn merge_histograms_rejects_shape_mismatch() {
        merge_histograms(&[1, 2], &[1, 2, 3]);
    }

    #[test]
    fn percentiles_are_order_invariant_across_shard_concatenation() {
        // percentile sorts internally, so pooling per-shard latency
        // vectors in any order yields the same percentiles — the
        // property the bench relies on when it concatenates shard
        // completions before computing p50/p95
        let shard_a = [5.0, 1.0, 9.0];
        let shard_b = [2.0, 7.0];
        let ab: Vec<f64> =
            shard_a.iter().chain(&shard_b).copied().collect();
        let ba: Vec<f64> =
            shard_b.iter().chain(&shard_a).copied().collect();
        for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
            assert_eq!(percentile(&ab, p), percentile(&ba, p));
        }
        assert_eq!(median(&ab), 5.0);
    }

    #[test]
    fn histogram_degenerate_range_is_all_zero() {
        // hi == lo used to divide by a zero bin width (NaN cast landed
        // everything in bin 0); now the histogram is explicitly empty
        assert_eq!(histogram(&[1.0, 2.0, 3.0], 2.0, 2.0, 4), vec![0; 4]);
        assert_eq!(histogram(&[1.0], 5.0, 1.0, 3), vec![0; 3]); // hi < lo
        assert!(histogram(&[1.0], 0.0, 1.0, 0).is_empty());
    }
}
