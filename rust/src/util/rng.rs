//! PCG32 pseudo-random number generator (O'Neill 2014).
//!
//! The `rand` crate is not vendored in this offline environment, so data
//! generation, initialization and property tests use this small,
//! well-understood generator.  Determinism across runs matters more here
//! than cryptographic quality: every experiment records its seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Fill a buffer with N(0, std^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        cumulative_pick(self.f64() * total, weights.iter().copied())
    }

    /// `f32` fast path of `weighted`: samples straight from `f32`
    /// weights without first copying them into a `Vec<f64>`,
    /// accumulating in `f64` so it picks exactly the index `weighted`
    /// picks on the same weights.  (The serving sampler keeps its own
    /// per-request RNG and goes through `cumulative_pick` directly;
    /// this entry point is for `Pcg32` users with `f32` weight
    /// arrays.)
    pub fn weighted_f32(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        cumulative_pick(self.f64() * total,
                        weights.iter().map(|&w| w as f64))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }
}

/// Walk a cumulative distribution: the first index whose weight pushes
/// the running total past `x`, where callers draw `x` uniform in
/// `[0, total)`.  Rounding that pushes `x` past the final weight falls
/// back to the last index.  Shared by `Pcg32::weighted`/`weighted_f32`
/// and the serving sampler (`model::sample`), so every weighted draw
/// in the tree resolves ties and rounding identically.
pub fn cumulative_pick<I>(mut x: f64, weights: I) -> usize
where
    I: ExactSizeIterator<Item = f64>,
{
    let last = weights.len().saturating_sub(1);
    for (i, w) in weights.enumerate() {
        if x < w {
            return i;
        }
        x -= w;
    }
    last
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Pcg32::seeded(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 100_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg32::seeded(6);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn weighted_f32_picks_the_same_index_as_the_f64_path() {
        // identical weights + identical RNG state: the f32 fast path
        // accumulates in f64, so every draw must resolve to the same
        // index the copy-to-f64 path resolves to — bit-exact
        let wf: Vec<f32> =
            (0..257).map(|i| ((i * 37) % 101) as f32 / 7.0).collect();
        let wd: Vec<f64> = wf.iter().map(|&w| w as f64).collect();
        let mut a = Pcg32::seeded(11);
        let mut b = Pcg32::seeded(11);
        for step in 0..4096 {
            let i = a.weighted(&wd);
            let j = b.weighted_f32(&wf);
            assert_eq!(i, j, "diverged at draw {step}");
        }
    }

    #[test]
    fn cumulative_pick_covers_rounding_overflow() {
        // x just past the total (rounding): fall back to the last index
        let w = [0.25f64, 0.25, 0.5];
        assert_eq!(cumulative_pick(0.0, w.iter().copied()), 0);
        assert_eq!(cumulative_pick(0.3, w.iter().copied()), 1);
        assert_eq!(cumulative_pick(0.99, w.iter().copied()), 2);
        assert_eq!(cumulative_pick(1.01, w.iter().copied()), 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
