//! PCG32 pseudo-random number generator (O'Neill 2014).
//!
//! The `rand` crate is not vendored in this offline environment, so data
//! generation, initialization and property tests use this small,
//! well-understood generator.  Determinism across runs matters more here
//! than cryptographic quality: every experiment records its seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn usize_below(&mut self, bound: usize) -> usize {
        self.below(bound as u32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Fill a buffer with N(0, std^2) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], std: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.usize_below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Pcg32::seeded(4);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(5);
        let n = 100_000;
        let (mut sum, mut sq) = (0f64, 0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg32::seeded(6);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
