//! Minimal JSON parser + writer (serde is not vendored offline).
//!
//! Covers everything this project needs: the AOT manifest, golden test
//! vectors, run configuration files and metrics logs.  Numbers are parsed
//! as f64 (the manifest only carries shapes/floats well within f64's exact
//! integer range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|x| Ok(x.as_f64()? as f32)).collect()
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|x| x.as_f64()).collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|x| Ok(x.as_f64()? as i32)).collect()
    }

    // -- io ------------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn read_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        Json::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected eof"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape"),
                    }
                }
                c => {
                    // re-assemble multibyte utf8 sequences
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| {
            format!("bad number {text:?} at byte {start}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(!j.get("c").unwrap().as_bool().unwrap());
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b").unwrap().as_str().unwrap(),
            "x"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,-3],"s":"a\"b\n","t":true,"n":null}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn escapes() {
        let j = Json::Str("line\nquote\"tab\t".into());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse("\"héllo ∆\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∆");
        let esc = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(esc.as_str().unwrap(), "Aé");
    }

    #[test]
    fn errors_on_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn typed_vectors() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(j.f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
    }
}
