//! Deterministic fault injection for the chaos suite.
//!
//! A *failpoint* is a named site in production code (`fail_point!`)
//! that does nothing until a test *arms* it, after which the matching
//! [`hit`] panics on a precisely chosen occurrence — turning "what if
//! the shard dies mid-decode?" into a reproducible unit test instead
//! of a hope about rare crashes.  The whole module only exists under
//! `cfg(any(test, feature = "failpoints"))`; in ordinary builds the
//! `fail_point!` macro expands to nothing, so the hot paths carry
//! zero cost.  Even when compiled in, an unarmed process takes one
//! relaxed atomic load per site visit.
//!
//! Arming is **site-keyed and counted**: [`arm`]`(site, n)` fires on
//! the n-th future visit to `site` and then disarms itself (one-shot),
//! so a test gets exactly one injected fault at an exact point in the
//! schedule.  [`arm_random`] instead flips a seeded coin on every
//! visit — same seed, same schedule of faults — for soak-style runs.
//!
//! The registry is **process-global**.  Tests that arm a site used by
//! live engine code must not run concurrently with other tests
//! touching that code path: the chaos tests that inject panics are
//! gated behind `feature = "failpoints"` and run single-threaded in
//! the dedicated analysis job (see `.github/workflows/analysis.yml`),
//! never in tier-1's parallel test run.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// What an armed site does on each visit.
enum Plan {
    /// Fire on the n-th future visit (1 = the very next), then disarm.
    CountDown(u64),
    /// Seeded coin flip per visit: fire with probability `p`.  Stays
    /// armed after firing — the seed alone determines the schedule.
    Random { rng: u64, p: f64 },
}

struct SiteState {
    site: &'static str,
    plan: Plan,
    /// visits observed *while armed* (diagnostics for tests)
    hits: u64,
}

/// Fast path: skip the registry lock entirely while nothing is armed.
static ARMED: AtomicBool = AtomicBool::new(false);
static SITES: Mutex<Vec<SiteState>> = Mutex::new(Vec::new());

fn with_sites<T>(f: impl FnOnce(&mut Vec<SiteState>) -> T) -> T {
    // a panic raised by `hit` never holds this lock (the guard is
    // dropped first), but recover poison anyway: the registry is plain
    // data with no invariant a panicking test could half-apply
    let mut g = SITES.lock().unwrap_or_else(|e| e.into_inner());
    f(&mut g)
}

/// splitmix64 step — the crate's stock dependency-free generator.
fn next_u64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Arm `site` to panic on its `after_hits`-th future visit
/// (`after_hits == 1` fires on the very next one), then disarm.
/// Re-arming an already-armed site replaces its plan.
pub fn arm(site: &'static str, after_hits: u64) {
    assert!(after_hits > 0, "after_hits is 1-based");
    with_sites(|sites| {
        sites.retain(|s| s.site != site);
        sites.push(SiteState {
            site,
            plan: Plan::CountDown(after_hits),
            hits: 0,
        });
    });
    ARMED.store(true, Ordering::Release);
}

/// Arm `site` to panic with probability `p` on every visit, driven by
/// a private splitmix64 stream seeded with `seed` — the same seed
/// reproduces the same fault schedule.  Stays armed after firing.
pub fn arm_random(site: &'static str, seed: u64, p: f64) {
    assert!((0.0..=1.0).contains(&p), "probability out of range: {p}");
    with_sites(|sites| {
        sites.retain(|s| s.site != site);
        sites.push(SiteState {
            site,
            plan: Plan::Random { rng: seed, p },
            hits: 0,
        });
    });
    ARMED.store(true, Ordering::Release);
}

/// Disarm one site (a site that already fired its one-shot is gone).
pub fn disarm(site: &str) {
    with_sites(|sites| {
        sites.retain(|s| s.site != site);
        if sites.is_empty() {
            ARMED.store(false, Ordering::Release);
        }
    });
}

/// Disarm everything — call at the start and end of any test that
/// arms, so a failed assertion cannot leak faults into later tests.
pub fn reset() {
    with_sites(|sites| sites.clear());
    ARMED.store(false, Ordering::Release);
}

/// Visits to `site` observed while it was armed (0 if never armed or
/// already disarmed — the one-shot clears its state when it fires).
pub fn observed_hits(site: &str) -> u64 {
    with_sites(|sites| {
        sites.iter().find(|s| s.site == site).map_or(0, |s| s.hits)
    })
}

/// The call `fail_point!` expands to: panic here if this site is
/// armed and its plan says this visit is the one.  The panic payload
/// names the site so supervisors/logs can attribute the fault.
pub fn hit(site: &str) {
    if !ARMED.load(Ordering::Acquire) {
        return;
    }
    let fire = with_sites(|sites| {
        let Some(i) = sites.iter().position(|s| s.site == site) else {
            return false;
        };
        sites[i].hits += 1;
        match &mut sites[i].plan {
            Plan::CountDown(n) => {
                *n -= 1;
                if *n == 0 {
                    sites.remove(i); // one-shot: disarm before firing
                    if sites.is_empty() {
                        ARMED.store(false, Ordering::Release);
                    }
                    true
                } else {
                    false
                }
            }
            Plan::Random { rng, p } => {
                // top 53 bits → uniform in [0, 1)
                let u = (next_u64(rng) >> 11) as f64 / (1u64 << 53) as f64;
                u < *p
            }
        }
    });
    // the registry lock is released before unwinding
    if fire {
        panic!("failpoint '{site}' fired");
    }
}

/// Compile-time no-op unless failpoints are compiled in; otherwise a
/// maybe-panic at the named site (see [`hit`]).
#[macro_export]
macro_rules! fail_point {
    ($site:expr) => {{
        #[cfg(any(test, feature = "failpoints"))]
        $crate::util::failpoint::hit($site);
    }};
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Site names here are private to these tests (never referenced by
    // engine code), so arming them cannot perturb concurrently running
    // serve tests.

    #[test]
    fn countdown_fires_on_exactly_the_nth_hit_then_disarms() {
        arm("fp-test-countdown", 3);
        hit("fp-test-countdown");
        hit("fp-test-countdown");
        let err = catch_unwind(AssertUnwindSafe(|| {
            hit("fp-test-countdown");
        }))
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("fp-test-countdown"), "{msg}");
        // one-shot: the site disarmed itself before firing
        hit("fp-test-countdown");
        assert_eq!(observed_hits("fp-test-countdown"), 0);
    }

    #[test]
    fn unarmed_sites_never_fire_and_disarm_clears() {
        hit("fp-test-unarmed");
        arm("fp-test-disarm", 1);
        disarm("fp-test-disarm");
        hit("fp-test-disarm"); // would panic if still armed
    }

    #[test]
    fn random_plan_is_seed_deterministic() {
        let schedule = |seed: u64| -> Vec<bool> {
            arm_random("fp-test-random", seed, 0.5);
            let out = (0..32)
                .map(|_| {
                    catch_unwind(AssertUnwindSafe(|| {
                        hit("fp-test-random")
                    }))
                    .is_err()
                })
                .collect();
            disarm("fp-test-random");
            out
        };
        let a = schedule(42);
        let b = schedule(42);
        let c = schedule(43);
        assert_eq!(a, b, "same seed must replay the same faults");
        assert!(a.iter().any(|&f| f), "p=0.5 over 32 draws never fired");
        assert!(a.iter().any(|&f| !f), "p=0.5 over 32 draws always fired");
        assert_ne!(a, c, "different seeds should diverge (32 draws)");
    }

    #[test]
    fn probability_bounds_are_respected() {
        arm_random("fp-test-p0", 7, 0.0);
        for _ in 0..64 {
            hit("fp-test-p0"); // p = 0: must never fire
        }
        assert_eq!(observed_hits("fp-test-p0"), 64);
        disarm("fp-test-p0");
        arm_random("fp-test-p1", 7, 1.0);
        let fired = catch_unwind(AssertUnwindSafe(|| hit("fp-test-p1")));
        assert!(fired.is_err(), "p = 1 must fire on the first visit");
        disarm("fp-test-p1");
    }
}
