//! Wall-clock benchmark harness (criterion is not vendored offline).
//!
//! Usage mirrors criterion's spirit: warm-up, multiple timed samples,
//! median + MAD reporting, and paper-style table printing so each bench
//! binary can regenerate one table/figure of the paper.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// median seconds per iteration
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub target_sample: Duration,
    pub samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(150),
            target_sample: Duration::from_millis(60),
            samples: 11,
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(30),
            target_sample: Duration::from_millis(15),
            samples: 5,
        }
    }

    /// Benchmark `f`, which performs one logical iteration per call.
    /// A `std::hint::black_box` around inputs/outputs is the caller's job.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warm-up and calibration: how many iters fit in target_sample?
        let wstart = Instant::now();
        let mut calib_iters = 0usize;
        while wstart.elapsed() < self.warmup || calib_iters == 0 {
            f();
            calib_iters += 1;
        }
        let per_iter = wstart.elapsed().as_secs_f64() / calib_iters as f64;
        let iters = ((self.target_sample.as_secs_f64() / per_iter).ceil()
            as usize)
            .max(1);

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        BenchResult {
            name: name.to_string(),
            median_s: median,
            mean_s: mean,
            min_s: times[0],
            samples: self.samples,
            iters_per_sample: iters,
        }
    }
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Paper-style table printer: fixed-width columns, markdown-ish.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            println!("{s}");
        };
        line(&self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        println!("{sep}");
        for row in &self.rows {
            line(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            target_sample: Duration::from_millis(5),
            samples: 3,
        };
        let r = b.run("sleep", || std::thread::sleep(Duration::from_micros(200)));
        assert!(r.median_s >= 150e-6, "{}", r.median_s);
        assert!(r.median_s < 10e-3, "{}", r.median_s);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // just exercising the formatting path
    }

    #[test]
    fn throughput() {
        let r = BenchResult {
            name: "t".into(),
            median_s: 0.5,
            mean_s: 0.5,
            min_s: 0.5,
            samples: 1,
            iters_per_sample: 1,
        };
        assert_eq!(r.throughput(100.0), 200.0);
    }
}
