//! Mini property-testing framework (proptest is not vendored offline).
//!
//! A property is a closure over a `Gen` (seeded PRNG + size hints).  The
//! runner executes it for many seeds and reports the failing seed on the
//! first panic-free `Err`, so failures are reproducible by construction.

use super::rng::Pcg32;

pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    /// Dimension that grows with the case index (small cases first, like
    /// proptest's sizing) in [1, max].
    pub fn dim(&mut self, max: usize) -> usize {
        let cap = (self.case / 4 + 2).min(max);
        1 + self.rng.usize_below(cap)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.usize_below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.usize_below(xs.len())]
    }

    pub fn vec_normal(&mut self, n: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v, std);
        v
    }

    /// A sparse non-negative vector with roughly `density` fraction of
    /// non-zeros (the bread-and-butter input for the sparse kernels).
    pub fn sparse_vec(&mut self, n: usize, density: f32) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if self.rng.f32() < density {
                    self.rng.f32() + 0.01
                } else {
                    0.0
                }
            })
            .collect()
    }
}

/// Run `prop` for `cases` seeds derived from `seed`.  Panics with the
/// failing case seed embedded in the message.
pub fn check<F>(name: &str, cases: usize, seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e37_79b9_7f4a_7c15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Pcg32::seeded(case_seed), case };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name:?} failed on case {case} (seed {case_seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 50, 1, |_g| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "always-false")]
    fn failing_property_panics_with_seed() {
        check("demo", 10, 2, |_g| Err("always-false".into()));
    }

    #[test]
    fn sparse_vec_density() {
        let mut g = Gen { rng: Pcg32::seeded(3), case: 0 };
        let v = g.sparse_vec(10_000, 0.1);
        let nnz = v.iter().filter(|&&x| x > 0.0).count();
        assert!((800..1200).contains(&nnz), "{nnz}");
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn dim_bounded() {
        let mut g = Gen { rng: Pcg32::seeded(4), case: 100 };
        for _ in 0..100 {
            let d = g.dim(16);
            assert!((1..=16).contains(&d));
        }
    }
}
