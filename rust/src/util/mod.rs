//! Infrastructure substrates built from scratch for the offline testbed:
//! PRNG (no `rand`), JSON codec (no `serde`), wall-clock bench harness
//! (no `criterion`), statistics helpers, a mini property-testing
//! framework (no `proptest`), and the loom-switchable synchronization
//! shim every thread in the process is created through.

pub mod bench;
#[cfg(any(test, feature = "failpoints"))]
pub mod failpoint;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub(crate) mod sync;

/// Convenient alias used across the crate.
pub type Result<T> = anyhow::Result<T>;
