//! Infrastructure substrates built from scratch for the offline testbed:
//! PRNG (no `rand`), JSON codec (no `serde`), wall-clock bench harness
//! (no `criterion`), statistics helpers, and a mini property-testing
//! framework (no `proptest`).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

/// Convenient alias used across the crate.
pub type Result<T> = anyhow::Result<T>;
