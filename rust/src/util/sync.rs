//! Synchronization shim — the one place the crate names its lock,
//! condvar and thread primitives.
//!
//! The worker pool (`sparse::par`) and the serving engine import
//! `Mutex` / `Condvar` / `thread` / `thread_local!` from here instead
//! of from `std::sync` directly.  A normal build re-exports the `std`
//! types unchanged (zero cost).  Building with `RUSTFLAGS="--cfg
//! loom"` swaps in [loom]'s model-checked replacements, which lets
//! `cargo test --release --lib loom_` exhaustively enumerate every
//! interleaving of the pool's lock/condvar protocol and of the serve
//! layer's shared admission queue instead of hoping the OS scheduler
//! stumbles onto the bad one (see `par::loom_tests`,
//! `serve::admission::loom_tests` and
//! `.github/workflows/analysis.yml`).
//!
//! Policy, enforced by `cargo run -p xtask -- check`: OS threads are
//! created only inside this module and `sparse/par.rs` (the pool's
//! workers and its tests).  Everything else — the serving engine
//! included — goes through [`spawn_named`], so the set of threads in
//! the process stays enumerable and the loom models stay a faithful
//! abstraction of the real concurrency.
//!
//! [loom]: https://docs.rs/loom

#[cfg(not(loom))]
pub(crate) use std::sync::{Condvar, Mutex, MutexGuard};
#[cfg(not(loom))]
pub(crate) use std::thread;
#[cfg(not(loom))]
pub(crate) use std::thread::JoinHandle;
#[cfg(not(loom))]
pub(crate) use std::thread_local;

#[cfg(loom)]
pub(crate) use loom::sync::{Condvar, Mutex, MutexGuard};
#[cfg(loom)]
pub(crate) use loom::thread;
#[cfg(loom)]
pub(crate) use loom::thread::JoinHandle;
#[cfg(loom)]
pub(crate) use loom::thread_local;

/// Condvar wait with a deadline, loom-switchable.  A normal build
/// delegates to `std`'s `wait_timeout` (poison recovered, since every
/// caller's state is valid under a poisoned lock).  Under loom it
/// degrades to a plain `wait` that never reports a timeout: loom has
/// no model of time, so a modeled protocol must be woken explicitly
/// (a notify after a push or a shutdown) — which is exactly what the
/// admission-queue models exercise.  Timeout-dependent behavior
/// (sequential batch filling) is therefore untestable under loom by
/// construction; keep protocol correctness independent of it.
pub(crate) fn wait_timeout<'a, T>(
    cv: &Condvar, guard: MutexGuard<'a, T>, dur: std::time::Duration,
) -> (MutexGuard<'a, T>, bool) {
    #[cfg(not(loom))]
    {
        let (guard, timeout) = cv
            .wait_timeout(guard, dur)
            .unwrap_or_else(|e| e.into_inner());
        (guard, timeout.timed_out())
    }
    #[cfg(loom)]
    {
        let _ = dur;
        let guard = cv.wait(guard).unwrap_or_else(|e| e.into_inner());
        (guard, false)
    }
}

/// Spawn a named OS thread.  The crate's front door for long-lived
/// non-pool threads (the serving engine's shard loops); the pool
/// spawns its own workers via `thread::Builder` in `sparse/par.rs`.
/// Under loom the name is dropped — loom threads are anonymous.
pub(crate) fn spawn_named<F>(name: &str, f: F) -> JoinHandle<()>
where
    F: FnOnce() + Send + 'static,
{
    #[cfg(not(loom))]
    {
        thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("failed to spawn thread")
    }
    #[cfg(loom)]
    {
        let _ = name;
        thread::spawn(f)
    }
}
