//! Data substrate: a synthetic fineweb-like corpus generator, a BPE
//! tokenizer trained from scratch, and a batching dataloader.
//!
//! Substitution note (DESIGN.md section 2): the paper pretrains on
//! fineweb-edu, which is unavailable offline.  The generator produces
//! web-crawl-shaped documents from a probabilistic grammar whose token
//! categories (URL fragments, contractions, content nouns/verbs,
//! boilerplate) are chosen so the paper's *token-level sparsity
//! phenomenology* (figure 7: link/contraction tokens cheap, content
//! tokens expensive, position-0 spike) has a measurable analogue.

pub mod bpe;
pub mod corpus;
pub mod loader;
