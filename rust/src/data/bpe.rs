//! Byte-pair-encoding tokenizer trained from scratch (GPT-2-tokenizer
//! stand-in; DESIGN.md section 2).
//!
//! Byte-level base vocabulary (256 ids) + 2 specials + learned merges up
//! to the target vocab size.  Training operates on a word-frequency table
//! with whitespace pre-segmentation (words carry a leading space marker,
//! like GPT-2's Ġ), which keeps training O(vocab * unique-words).

use std::collections::HashMap;

use anyhow::{bail, Result};

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const N_SPECIAL: usize = 2;

#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge list in training order: (left, right) -> new id
    pub merges: Vec<(u32, u32)>,
    /// rank lookup for encoding
    ranks: HashMap<(u32, u32), u32>,
    /// id -> byte string
    pub vocab_bytes: Vec<Vec<u8>>,
}

/// Split text into pre-tokenization words: leading-space-attached
/// alphanumeric runs or single punctuation.
fn pre_tokenize(text: &str) -> Vec<&str> {
    let bytes = text.as_bytes();
    let mut words = Vec::new();
    let mut start = 0;
    let mut i = 0;
    let is_word = |b: u8| b.is_ascii_alphanumeric() || b == b'\'';
    while i < bytes.len() {
        // a word = optional single space + run of same class
        let ws_len = usize::from(bytes[i] == b' ' || bytes[i] == b'\n');
        let j = i + ws_len;
        if j >= bytes.len() {
            words.push(&text[start..]);
            break;
        }
        let class_word = is_word(bytes[j]);
        let mut k = j + 1;
        while k < bytes.len() && is_word(bytes[k]) == class_word
            && bytes[k] != b' ' && bytes[k] != b'\n'
        {
            if !class_word {
                break; // punctuation: one char per token
            }
            k += 1;
        }
        words.push(&text[start..k]);
        start = k;
        i = k;
    }
    words.retain(|w| !w.is_empty());
    words
}

impl Bpe {
    /// Train to `vocab_size` total ids (256 bytes + specials + merges).
    pub fn train(text: &str, vocab_size: usize) -> Result<Bpe> {
        if vocab_size < 256 + N_SPECIAL {
            bail!("vocab_size must be at least {}", 256 + N_SPECIAL);
        }
        // word frequency table as id sequences
        let mut word_freq: HashMap<Vec<u32>, u64> = HashMap::new();
        for w in pre_tokenize(text) {
            let ids: Vec<u32> = w.bytes().map(|b| b as u32).collect();
            *word_freq.entry(ids).or_insert(0) += 1;
        }
        let mut vocab_bytes: Vec<Vec<u8>> =
            (0u8..=255).map(|b| vec![b]).collect();
        vocab_bytes.push(b"<bos>".to_vec());
        vocab_bytes.push(b"<eos>".to_vec());

        let mut merges = Vec::new();
        let mut words: Vec<(Vec<u32>, u64)> = word_freq.into_iter().collect();
        words.sort(); // determinism independent of hash order

        while vocab_bytes.len() < vocab_size {
            // count adjacent pairs
            let mut pair_counts: HashMap<(u32, u32), u64> = HashMap::new();
            for (ids, freq) in &words {
                for win in ids.windows(2) {
                    *pair_counts.entry((win[0], win[1])).or_insert(0) += freq;
                }
            }
            // best pair (ties broken deterministically by pair value)
            let Some((&best, &count)) = pair_counts
                .iter()
                .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(a.0)))
            else {
                break;
            };
            if count < 2 {
                break; // nothing productive left to merge
            }
            let new_id = vocab_bytes.len() as u32;
            let mut merged = vocab_bytes[best.0 as usize].clone();
            merged.extend_from_slice(&vocab_bytes[best.1 as usize]);
            vocab_bytes.push(merged);
            merges.push(best);
            // apply merge to every word
            for (ids, _) in words.iter_mut() {
                let mut out = Vec::with_capacity(ids.len());
                let mut i = 0;
                while i < ids.len() {
                    if i + 1 < ids.len() && (ids[i], ids[i + 1]) == best {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(ids[i]);
                        i += 1;
                    }
                }
                *ids = out;
            }
        }
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        Ok(Bpe { merges, ranks, vocab_bytes })
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_bytes.len()
    }

    /// Encode text to token ids (greedy lowest-rank merging per word).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for w in pre_tokenize(text) {
            let mut ids: Vec<u32> = w.bytes().map(|b| b as u32).collect();
            loop {
                let mut best: Option<(u32, usize)> = None; // (rank, pos)
                for (i, win) in ids.windows(2).enumerate() {
                    if let Some(&r) = self.ranks.get(&(win[0], win[1])) {
                        if best.map(|(br, _)| r < br).unwrap_or(true) {
                            best = Some((r, i));
                        }
                    }
                }
                match best {
                    None => break,
                    Some((rank, pos)) => {
                        let new_id = 256 + N_SPECIAL as u32 + rank;
                        ids.splice(pos..pos + 2, [new_id]);
                    }
                }
            }
            out.extend(ids);
        }
        out
    }

    /// Decode token ids back to text (lossless for valid utf-8 inputs).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id as usize >= self.vocab_bytes.len() || id == BOS || id == EOS
            {
                continue;
            }
            bytes.extend_from_slice(&self.vocab_bytes[id as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Human-readable token string (for the figure-7 token tables).
    pub fn token_str(&self, id: u32) -> String {
        String::from_utf8_lossy(&self.vocab_bytes[id as usize]).into_owned()
    }

    // -- persistence (own binary-ish JSON format) ---------------------------
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            (
                "merges",
                Json::Arr(
                    self.merges
                        .iter()
                        .map(|&(a, b)| {
                            Json::Arr(vec![
                                Json::Num(a as f64),
                                Json::Num(b as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> Result<Bpe> {
        let mut vocab_bytes: Vec<Vec<u8>> =
            (0u8..=255).map(|b| vec![b]).collect();
        vocab_bytes.push(b"<bos>".to_vec());
        vocab_bytes.push(b"<eos>".to_vec());
        let mut merges = Vec::new();
        for pair in j.get("merges")?.as_arr()? {
            let p = pair.as_arr()?;
            let a = p[0].as_f64()? as u32;
            let b = p[1].as_f64()? as u32;
            let mut m = vocab_bytes[a as usize].clone();
            m.extend_from_slice(&vocab_bytes[b as usize]);
            vocab_bytes.push(m);
            merges.push((a, b));
        }
        let ranks = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        Ok(Bpe { merges, ranks, vocab_bytes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the river borders the valley . the river drains \
                          the basin . source : www nih gov / doi 4821 . \
                          it doesn 't match the coast .";

    #[test]
    fn roundtrip_lossless() {
        let bpe = Bpe::train(SAMPLE, 300).unwrap();
        let ids = bpe.encode(SAMPLE);
        assert_eq!(bpe.decode(&ids), SAMPLE);
    }

    #[test]
    fn training_compresses() {
        let text = SAMPLE.repeat(20);
        let bpe = Bpe::train(&text, 320).unwrap();
        let ids = bpe.encode(&text);
        assert!(ids.len() < text.len() / 2,
                "{} tokens for {} bytes", ids.len(), text.len());
    }

    #[test]
    fn frequent_words_become_single_tokens() {
        let text = " the the the the the the river river river".repeat(50);
        let bpe = Bpe::train(&text, 280).unwrap();
        let ids = bpe.encode(" the");
        assert_eq!(ids.len(), 1, "{ids:?}");
    }

    #[test]
    fn vocab_size_respected() {
        let bpe = Bpe::train(&SAMPLE.repeat(10), 290).unwrap();
        assert!(bpe.vocab_size() <= 290);
        let ids = bpe.encode(SAMPLE);
        assert!(ids.iter().all(|&i| (i as usize) < bpe.vocab_size()));
    }

    #[test]
    fn serde_roundtrip() {
        use crate::util::json::Json;
        let bpe = Bpe::train(&SAMPLE.repeat(5), 300).unwrap();
        let j = bpe.to_json();
        let back = Bpe::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(bpe.merges, back.merges);
        assert_eq!(bpe.encode(SAMPLE), back.encode(SAMPLE));
    }

    #[test]
    fn unknown_bytes_still_roundtrip() {
        let bpe = Bpe::train(SAMPLE, 280).unwrap();
        let text = "zzz qqq ###";
        let ids = bpe.encode(text);
        assert!(!ids.is_empty());
        assert_eq!(bpe.decode(&ids), text);
    }
}
