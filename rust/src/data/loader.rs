//! Dataset + batching dataloader over the tokenized corpus.
//!
//! The token stream is the concatenation of all documents separated by
//! EOS; training batches are `[batch, seq+1]` windows sampled without
//! replacement per epoch (deterministic given the seed), matching how the
//! python train_step slices inputs/targets.

use crate::data::bpe::{Bpe, EOS};
use crate::data::corpus::{self, CorpusSpec};
use crate::util::rng::Pcg32;

pub struct Dataset {
    pub tokens: Vec<u32>,
    pub vocab_size: usize,
}

impl Dataset {
    /// Build corpus -> tokenizer -> token stream in one go.
    pub fn synthetic(spec: &CorpusSpec, vocab_size: usize) -> (Dataset, Bpe) {
        let docs = corpus::generate(spec);
        let text: Vec<&str> = docs.iter().map(|(_, d)| d.as_str()).collect();
        let joined = text.join("\n");
        let bpe = Bpe::train(&joined, vocab_size).expect("bpe train");
        let mut tokens = Vec::new();
        for d in &text {
            tokens.extend(bpe.encode(d));
            tokens.push(EOS);
        }
        let vs = bpe.vocab_size();
        (Dataset { tokens, vocab_size: vs }, bpe)
    }

    pub fn n_windows(&self, seq: usize) -> usize {
        self.tokens.len().saturating_sub(seq + 1)
    }
}

/// Epoch-shuffled window sampler.
pub struct Loader<'a> {
    data: &'a Dataset,
    pub batch: usize,
    pub seq: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Pcg32,
    /// stride between candidate window starts (1 = fully overlapping)
    pub stride: usize,
}

impl<'a> Loader<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seq: usize, seed: u64) -> Self {
        let stride = (seq / 2).max(1);
        let n = data.n_windows(seq) / stride;
        assert!(n >= batch, "corpus too small: {n} windows for batch {batch}");
        let mut l = Loader {
            data,
            batch,
            seq,
            order: (0..n).map(|i| i * stride).collect(),
            cursor: 0,
            rng: Pcg32::seeded(seed),
            stride,
        };
        l.reshuffle();
        l
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Next `[batch, seq+1]` i32 batch, row-major flattened.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * (self.seq + 1));
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.reshuffle();
            }
            let start = self.order[self.cursor];
            self.cursor += 1;
            out.extend(
                self.data.tokens[start..start + self.seq + 1]
                    .iter()
                    .map(|&t| t as i32),
            );
        }
        out
    }

    /// `k` consecutive batches flattened (for the train_step8 artifact).
    pub fn next_batches(&mut self, k: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(k * self.batch * (self.seq + 1));
        for _ in 0..k {
            out.extend(self.next_batch());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dataset() -> (Dataset, Bpe) {
        let spec = CorpusSpec { n_docs: 60, seed: 7, ..CorpusSpec::default() };
        Dataset::synthetic(&spec, 300)
    }

    #[test]
    fn tokens_in_vocab_range() {
        let (ds, _) = small_dataset();
        assert!(ds.tokens.iter().all(|&t| (t as usize) < ds.vocab_size));
        assert!(ds.tokens.len() > 1000);
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let (ds, _) = small_dataset();
        let mut l = Loader::new(&ds, 4, 32, 0);
        let b = l.next_batch();
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < ds.vocab_size));
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, _) = small_dataset();
        let mut a = Loader::new(&ds, 4, 32, 42);
        let mut b = Loader::new(&ds, 4, 32, 42);
        assert_eq!(a.next_batch(), b.next_batch());
        assert_eq!(a.next_batches(3), b.next_batches(3));
    }

    #[test]
    fn epoch_wraps_without_panic() {
        let (ds, _) = small_dataset();
        let mut l = Loader::new(&ds, 8, 32, 1);
        let n_batches = l.order.len() / 8 + 3; // force a reshuffle
        for _ in 0..n_batches {
            let _ = l.next_batch();
        }
    }

    #[test]
    fn windows_are_contiguous_corpus_slices() {
        let (ds, _) = small_dataset();
        let mut l = Loader::new(&ds, 1, 16, 9);
        let b = l.next_batch();
        // find the window in the source stream
        let w: Vec<u32> = b.iter().map(|&t| t as u32).collect();
        let found = ds.tokens.windows(17).any(|win| win == w.as_slice());
        assert!(found);
    }
}
