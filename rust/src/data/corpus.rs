//! Synthetic web-corpus generator (fineweb stand-in, DESIGN.md section 2).
//!
//! Documents are drawn from a topic-conditioned probabilistic grammar:
//!   * each document picks a topic (geography / chemistry / history /
//!     medicine) that reweights its noun and verb distributions,
//!   * sentences come from a small set of templates with Zipfian word
//!     choice within each part of speech,
//!   * a fraction of sentences carry web boilerplate — citations with
//!     URL fragments (`www nih gov`, `doi`) and contractions
//!     (`doesn 't`) — that make the following token nearly deterministic.
//!
//! The determinism gradient is the point: the paper (figure 7) finds that
//! LLMs allocate few non-zero activations to predictable tokens (link
//! fragments, contraction stems) and many to high-information content
//! words; this corpus reproduces that predictability structure so the
//! analysis drivers can look for the same pattern.

use crate::util::rng::Pcg32;

/// Topic labels double as eval-task classes.
pub const TOPICS: [&str; 4] = ["geography", "chemistry", "history", "medicine"];

pub const DETERMINERS: [&str; 4] = ["the", "a", "this", "its"];
pub const PREPOSITIONS: [&str; 5] = ["of", "in", "from", "near", "with"];
pub const ADJECTIVES: [&str; 10] = [
    "enduring", "loud", "ancient", "notable", "common", "rare", "vast",
    "pure", "stable", "early",
];
pub const CONNECTIVES: [&str; 4] = ["and", "but", "while", "because"];

/// Topic-specific nouns (the "Vermont / formaldehyde / Greeks" analogues).
pub const NOUNS: [[&str; 8]; 4] = [
    ["vermont", "ridge", "valley", "river", "plateau", "coast", "border",
     "basin"],
    ["formaldehyde", "ethanol", "polymer", "acid", "solvent", "catalyst",
     "compound", "residue"],
    ["greeks", "empire", "dynasty", "treaty", "archive", "fleet",
     "settlement", "census"],
    ["ach", "enzyme", "receptor", "dosage", "membrane", "lesion",
     "antibody", "syndrome"],
];

pub const VERBS: [[&str; 6]; 4] = [
    ["borders", "drains", "rises", "spans", "erodes", "floods"],
    ["reacts", "binds", "dissolves", "oxidizes", "catalyzes", "precipitates"],
    ["conquered", "recorded", "traded", "declined", "rebuilt", "governed"],
    ["inhibits", "activates", "regulates", "signals", "absorbs", "secretes"],
];

/// Contraction stems: the token after them is (almost) deterministic.
pub const CONTRACTIONS: [&str; 4] = ["doesn", "couldn", "wasn", "isn"];

/// URL fragments for the citation boilerplate.
pub const URL_PARTS: [&str; 6] = ["www", "nih", "gov", "doi", "nlm", "org"];

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub n_docs: usize,
    pub sentences_per_doc: (usize, usize), // inclusive range
    pub citation_prob: f64,
    pub contraction_prob: f64,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            n_docs: 2000,
            sentences_per_doc: (4, 10),
            citation_prob: 0.25,
            contraction_prob: 0.2,
            seed: 1234,
        }
    }
}

/// Zipfian weights over `n` ranks (w_i ~ 1/(i+1)).
fn zipf_weights(n: usize) -> Vec<f64> {
    (0..n).map(|i| 1.0 / (i as f64 + 1.0)).collect()
}

pub struct Generator {
    rng: Pcg32,
    noun_w: Vec<f64>,
    verb_w: Vec<f64>,
}

impl Generator {
    pub fn new(seed: u64) -> Self {
        Generator {
            rng: Pcg32::seeded(seed),
            noun_w: zipf_weights(NOUNS[0].len()),
            verb_w: zipf_weights(VERBS[0].len()),
        }
    }

    fn noun(&mut self, topic: usize) -> &'static str {
        NOUNS[topic][self.rng.weighted(&self.noun_w)]
    }

    fn verb(&mut self, topic: usize) -> &'static str {
        VERBS[topic][self.rng.weighted(&self.verb_w)]
    }

    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[self.rng.usize_below(xs.len())]
    }

    /// One sentence from the template grammar.
    fn sentence(&mut self, topic: usize, spec: &CorpusSpec) -> String {
        let mut words: Vec<String> = Vec::with_capacity(16);
        if self.rng.f64() < spec.citation_prob {
            // web boilerplate: "source : www nih gov / doi 4821 ."
            words.push("source".into());
            words.push(":".into());
            // url fragments appear in near-fixed order => very predictable
            words.push("www".into());
            words.push(self.pick(&["nih", "nlm", "gov"]).to_string());
            words.push("gov".into());
            words.push("/".into());
            words.push("doi".into());
            words.push(format!("{}", 1000 + self.rng.below(9000)));
        } else {
            words.push(self.pick(&DETERMINERS).to_string());
            if self.rng.f64() < 0.5 {
                words.push(self.pick(&ADJECTIVES).to_string());
            }
            words.push(self.noun(topic).to_string());
            if self.rng.f64() < spec.contraction_prob {
                // contraction stem + deterministic continuation
                words.push(self.pick(&CONTRACTIONS).to_string());
                words.push("'t".into());
                words.push("match".into());
            } else {
                words.push(self.verb(topic).to_string());
            }
            words.push(self.pick(&DETERMINERS).to_string());
            words.push(self.noun(topic).to_string());
            if self.rng.f64() < 0.6 {
                words.push(self.pick(&PREPOSITIONS).to_string());
                words.push(self.pick(&DETERMINERS).to_string());
                words.push(self.noun(topic).to_string());
            }
            if self.rng.f64() < 0.3 {
                words.push(self.pick(&CONNECTIVES).to_string());
                words.push(self.pick(&DETERMINERS).to_string());
                words.push(self.noun(topic).to_string());
                words.push(self.verb(topic).to_string());
            }
        }
        words.push(".".into());
        words.join(" ")
    }

    /// One document: topic header + sentences (the header makes topic a
    /// learnable, probe-able property).
    pub fn document(&mut self, spec: &CorpusSpec) -> (usize, String) {
        let topic = self.rng.usize_below(TOPICS.len());
        let (lo, hi) = spec.sentences_per_doc;
        let n = lo + self.rng.usize_below(hi - lo + 1);
        let mut out = format!("topic {} :", TOPICS[topic]);
        for _ in 0..n {
            out.push(' ');
            out.push_str(&self.sentence(topic, spec));
        }
        (topic, out)
    }
}

/// Generate the full corpus; returns (topic, text) per document.
pub fn generate(spec: &CorpusSpec) -> Vec<(usize, String)> {
    let mut g = Generator::new(spec.seed);
    (0..spec.n_docs).map(|_| g.document(spec)).collect()
}

/// Concatenate documents into one training text separated by newlines.
pub fn corpus_text(spec: &CorpusSpec) -> String {
    generate(spec)
        .into_iter()
        .map(|(_, d)| d)
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let spec = CorpusSpec { n_docs: 5, ..CorpusSpec::default() };
        assert_eq!(generate(&spec), generate(&spec));
    }

    #[test]
    fn different_seeds_differ() {
        let a = CorpusSpec { n_docs: 5, seed: 1, ..CorpusSpec::default() };
        let b = CorpusSpec { n_docs: 5, seed: 2, ..CorpusSpec::default() };
        assert_ne!(generate(&a), generate(&b));
    }

    #[test]
    fn documents_have_topic_header() {
        let spec = CorpusSpec { n_docs: 20, ..CorpusSpec::default() };
        for (topic, text) in generate(&spec) {
            assert!(text.starts_with(&format!("topic {} :", TOPICS[topic])));
        }
    }

    #[test]
    fn corpus_contains_boilerplate_and_content() {
        let spec = CorpusSpec { n_docs: 200, ..CorpusSpec::default() };
        let text = corpus_text(&spec);
        assert!(text.contains("doi"));
        assert!(text.contains("'t"));
        // at least one topical noun from each topic
        for nouns in NOUNS {
            assert!(nouns.iter().any(|n| text.contains(n)));
        }
    }

    #[test]
    fn contraction_followed_by_apostrophe_t() {
        let spec = CorpusSpec { n_docs: 300, ..CorpusSpec::default() };
        let text = corpus_text(&spec);
        for stem in CONTRACTIONS {
            let mut rest = text.as_str();
            while let Some(i) = rest.find(&format!(" {stem} ")) {
                let after = &rest[i + stem.len() + 2..];
                assert!(after.starts_with("'t "),
                        "contraction {stem} not followed by 't");
                rest = after;
            }
        }
    }

    #[test]
    fn topics_roughly_uniform() {
        let spec = CorpusSpec { n_docs: 2000, ..CorpusSpec::default() };
        let mut counts = [0usize; 4];
        for (t, _) in generate(&spec) {
            counts[t] += 1;
        }
        for c in counts {
            assert!((300..700).contains(&c), "{counts:?}");
        }
    }
}
