//! Row-range parallelism on scoped std threads (rayon is not vendored in
//! this offline environment).  All sparse kernels parallelize over
//! disjoint output-row blocks — the CPU rendering of "one CTA per row
//! (block)" — so a static block split suffices.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads (cached; overridable via REPRO_THREADS).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("REPRO_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1);
    N.store(n, Ordering::Relaxed);
    n
}

/// Run `f(lo, hi)` over a static partition of `0..m` across threads.
/// `f` must only touch output rows in its range (disjointness is the
/// caller's contract — identical to CUDA grid semantics).
pub fn for_row_blocks<F>(m: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let t = num_threads().min(m.max(1));
    if t <= 1 || m < 32 {
        f(0, m);
        return;
    }
    let chunk = m.div_ceil(t);
    std::thread::scope(|s| {
        for i in 0..t {
            let lo = i * chunk;
            let hi = ((i + 1) * chunk).min(m);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Like `for_row_blocks` but hands each block a disjoint mutable slice of
/// `out` (rows of width `row_w`).
pub fn for_row_blocks_out<F>(m: usize, row_w: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), m * row_w);
    let t = num_threads().min(m.max(1));
    if t <= 1 || m < 32 {
        f(0, m, out);
        return;
    }
    let chunk = m.div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = out;
        for i in 0..t {
            let lo = i * chunk;
            let hi = ((i + 1) * chunk).min(m);
            if lo >= hi {
                break;
            }
            let (mine, tail) = rest.split_at_mut((hi - lo) * row_w);
            rest = tail;
            let f = &f;
            s.spawn(move || f(lo, hi, mine));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_rows_exactly_once() {
        let hits = AtomicU64::new(0);
        for_row_blocks(1000, |lo, hi| {
            for _ in lo..hi {
                hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn out_variant_writes_disjoint_slices() {
        let mut out = vec![0f32; 100 * 4];
        for_row_blocks_out(100, 4, &mut out, |lo, _hi, block| {
            for (i, row) in block.chunks_mut(4).enumerate() {
                row.fill((lo + i) as f32);
            }
        });
        for r in 0..100 {
            assert_eq!(out[r * 4], r as f32);
        }
    }

    #[test]
    fn small_inputs_run_serial() {
        let mut out = vec![0f32; 8];
        for_row_blocks_out(8, 1, &mut out, |lo, hi, block| {
            assert_eq!((lo, hi), (0, 8));
            block.fill(1.0);
        });
        assert!(out.iter().all(|&x| x == 1.0));
    }
}
