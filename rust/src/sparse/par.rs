//! Kernel parallelism on a **persistent worker pool** (rayon is not
//! vendored in this offline environment).
//!
//! The seed implementation spawned scoped OS threads per kernel call —
//! fine for one big prefill GEMM, ruinous for autoregressive decode,
//! where every engine iteration launches dozens of skinny kernels and
//! each paid a `thread::scope` spawn/join.  This module instead parks a
//! pool of workers on a condvar and hands them generation-counted job
//! descriptors: dispatch is a mutex bump + `notify_all`, microseconds
//! instead of thread spawns, and the pool is shared process-wide.
//!
//! Two partitioning shapes, both the CPU rendering of "one CTA per
//! output block":
//!
//! * **Row blocks** (`for_row_blocks`, `for_row_blocks_out`) — a static
//!   split of the output rows, the right shape when M is large
//!   (prefill, training).
//! * **Column blocks** (`for_col_blocks`) — a static split of the
//!   output *columns*, the right shape when M is skinny (decode at
//!   batch ≤ 16): every core works on the same few rows, each owning a
//!   disjoint column range.
//!
//! Determinism contract: a job's closure may touch only the output
//! range it is handed (disjoint writes, identical to CUDA grid
//! semantics), and must compute each output element with the same
//! sequential instruction order regardless of where the partition
//! boundaries fall.  Every kernel built on top of this module keeps
//! that discipline, which is why results are **bit-exact for any
//! thread count and either dispatch shape** — the property the serving
//! engine's stream-parity tests pin down.
//!
//! # Concurrency invariants
//!
//! The pool's synchronization protocol, in the order a reviewer (or a
//! loom model) should check it:
//!
//! * **Lock order.**  `submit` is taken first, and only by submitters;
//!   `state` is taken second (by submitters) or alone (by workers).
//!   No path acquires `submit` while holding `state`, so the order is
//!   acyclic and deadlock-free.
//! * **One job in flight.**  `submit` serializes `run_pooled`, so
//!   `state.job` / `remaining` / `generation` always describe at most
//!   one job, and `ensure_workers` only runs with no job in flight.
//! * **Borrow liveness (the `WaitGuard` argument).**  `Job.data`
//!   erases a `&F` living on the submitter's stack.  The submitter
//!   arms a [`WaitGuard`] *before* running its own partition and drops
//!   it on every exit path — including unwinding out of its own
//!   partition's panic — and the guard's drop blocks until
//!   `remaining == 0`.  A worker decrements `remaining` (under
//!   `state`) only *after* its last use of `job.data`, so no worker
//!   can touch the closure once the guard returns: the borrow strictly
//!   outlives every dereference.
//! * **Parked workers never hold a job.**  Workers park on `work_cv`
//!   holding only `state` (released while waiting) and re-check
//!   `generation` on every wakeup.  A worker that wakes into a
//!   generation whose job already drained observes `job == None`
//!   (cleared by the guard under the same lock) and parks again;
//!   participants cannot lag past completion because completion *is*
//!   the sum of their decrements.
//! * **Poisoning is benign.**  Every `state` access goes through
//!   [`Pool::lock_state`], which unwraps poison via `into_inner`: the
//!   state is plain counters plus a `Copy` job descriptor — consistent
//!   at any instant a panic could unwind through the lock — and a
//!   panicking kernel closure is already reported via `panicked`.
//!   Wedging every later kernel call on a poisoned mutex would turn
//!   one kernel bug into a process-wide outage.
//! * **Panic propagation.**  Worker panics are caught in the worker
//!   loop, recorded in `panicked`, and re-raised on the submitter
//!   after the completion barrier; the submitter's own panic resumes
//!   unwinding after the guard has drained the job.
//!
//! These transitions are machine-checked: `loom_tests` (build with
//! `RUSTFLAGS="--cfg loom"`, run `cargo test --release --lib loom_`)
//! drives dispatch/wakeup, narrow fan-out, unwind-drain, panic-flag
//! and double-submitter interleavings through loom's model checker,
//! using the [`crate::util::sync`] shim that swaps every primitive
//! here for its loom twin.  See `.github/workflows/analysis.yml`.
//!
//! # Process-global knobs
//!
//! [`set_threads`] and [`set_skinny_fast_path`] are **process-global**:
//! each writes one shared atomic that every kernel call on every
//! thread reads at dispatch time.  There is no per-engine or
//! per-thread override — flipping a knob mid-flight retargets every
//! concurrent kernel in the process, including other serving engines'.
//! `REPRO_THREADS` seeds the same global on first use; `set_threads`
//! (the `--threads` serving flag) overrides it at any time — the pool
//! grows lazily and never shrinks, only the partition count changes.
//! Nested calls from inside a pool job run sequentially instead of
//! deadlocking on the single job slot.
//!
//! Because every kernel is bit-exact across all knob settings, a
//! concurrent flip can never change anyone's *results* — only their
//! scheduling.  But tests that **sweep** the knobs and assert on
//! which path ran (the determinism suites, the dispatch-counter
//! tests) would race each other under `cargo test`'s threaded runner;
//! they must hold [`test_guard`] for the duration of the sweep, and
//! restore the original settings before releasing it.
//!
//! # Per-shard thread budgeting
//!
//! Sharded serving (`ServePolicy::shards`) does **not** give each
//! shard its own pool or knob — the pool's single job slot already
//! serializes concurrent kernel calls, so N shard engines interleave
//! whole steps on one set of workers.  A shard's "thread budget" is
//! therefore just the partition count its steps fan out to: callers
//! split a total budget with [`threads_per_shard`] and call
//! [`set_threads`] once (the serve CLI, example and bench all do), so
//! each serialized step uses `total / shards` cores and the machine
//! is never oversubscribed by `shards × total` partitions.  Since the
//! kernels are bit-exact for any partition count, this splitting
//! never perturbs served streams — only step latency.

#[cfg(not(loom))]
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::OnceLock;

use crate::util::sync::{thread_local, Condvar, Mutex, MutexGuard};

/// Row count at which row-blocking amortizes; below it the skinny
/// kernels dispatch column-parallel (the seed dispatch simply went
/// sequential here — see `set_skinny_fast_path`).
pub(crate) const ROW_PAR_MIN_ROWS: usize = 32;

/// Minimum output elements (`m * row_w`) before a row-parallel kernel
/// is worth waking the pool for.
pub(crate) const PAR_MIN_ROW_WORK: usize = 4096;

/// Minimum per-job work (`n * col_w`, roughly flops) before a
/// column-parallel kernel is worth waking the pool for.  Column jobs
/// carry a flop-like weight because the skinny shapes they serve have
/// tiny outputs but long reduction dimensions.
pub(crate) const PAR_MIN_COL_WORK: usize = 32_768;

static THREADS: AtomicUsize = AtomicUsize::new(0);
static SKINNY_FAST_PATH: AtomicBool = AtomicBool::new(true);

/// Number of partitions a kernel fans out to (cached; REPRO_THREADS or
/// `set_threads` overrides, default = available parallelism).
pub fn num_threads() -> usize {
    let cached = THREADS.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("REPRO_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .max(1);
    THREADS.store(n, Ordering::Relaxed);
    n
}

/// Set the partition count (the `--threads` serving flag).  Takes
/// effect for every subsequent kernel call: the pool spawns missing
/// workers on demand, so raising the count mid-process is safe, and
/// results are bit-exact across any setting (see the module docs).
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Partition count for each of `shards` serving engines splitting a
/// `total` thread budget (the `--threads` flag is a *total*; see
/// "Per-shard thread budgeting" in the module docs).  Integer
/// division, clamped so every shard keeps at least one partition —
/// leftover threads (`total % shards`) stay idle rather than making
/// one shard's steps faster than its siblings'.
pub fn threads_per_shard(total: usize, shards: usize) -> usize {
    (total / shards.max(1)).max(1)
}

/// Toggle the skinny-batch fast path (default on).  When off, kernels
/// reproduce the **seed dispatch**: row-parallel only, with the blunt
/// `m < 32` sequential cutoff — i.e. every decode-shaped kernel on one
/// core.  The serve bench A/Bs the two paths; everything else should
/// leave this alone.
pub fn set_skinny_fast_path(on: bool) {
    SKINNY_FAST_PATH.store(on, Ordering::Relaxed);
}

pub(crate) fn skinny_fast_path() -> bool {
    SKINNY_FAST_PATH.load(Ordering::Relaxed)
}

/// Should a skinny (m-row) kernel with `n` output columns of ~`col_w`
/// work each take the column-parallel path?
pub(crate) fn use_col_dispatch(m: usize, n: usize, col_w: usize) -> bool {
    skinny_col_dispatch(m)
        && n >= 2
        && n.saturating_mul(col_w) >= PAR_MIN_COL_WORK
}

/// Shape-only half of the column-dispatch predicate: would a batch of
/// `m` rows *aim* for the column-parallel path under the current
/// knobs?  (Individual kernels add their work cutoffs on top.)  The
/// decode router's dispatch counters use this to label non-routed FFN
/// calls `col` vs `row`.
pub(crate) fn skinny_col_dispatch(m: usize) -> bool {
    skinny_fast_path() && m < ROW_PAR_MIN_ROWS && num_threads() > 1
}

/// Raw pointer wrapper for disjoint-range writes from pool workers
/// (the caller's contract: no two ranges overlap).
pub(crate) struct SendPtr<T>(*mut T);
// SAFETY: the wrapped pointer is only handed to pool workers that
// write *disjoint* ranges behind it (the partitioners' contract), and
// the submitter's completion barrier keeps the pointee alive and
// un-reborrowed until every worker is done.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: same argument — a `&SendPtr` only exposes the raw pointer
// value, and every dereference made through it targets a range no
// other thread touches.
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    pub(crate) fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    /// Method (not field) access so edition-2021 closures capture the
    /// Sync wrapper rather than the raw pointer field.
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

// ---------------------------------------------------------------------
// The pool
// ---------------------------------------------------------------------

/// One dispatched job: an erased borrow of the caller's closure plus
/// the partition geometry.  Worker `i` executes range
/// `[i * chunk, min((i + 1) * chunk, len))` when `i < parts`.
#[derive(Clone, Copy)]
struct Job {
    /// Points at the caller's `&F`; only dereferenced through `call`
    /// while the submitting thread blocks in `WaitGuard`, which keeps
    /// the borrow alive.
    data: *const (),
    call: unsafe fn(*const (), usize, usize),
    len: usize,
    chunk: usize,
    parts: usize,
}
// SAFETY: `data` crosses threads but is only used via `call` under the
// submitter's completion barrier, and `run_pooled` requires `F: Sync`.
unsafe impl Send for Job {}

struct PoolState {
    generation: u64,
    job: Option<Job>,
    /// participating workers that have not finished the current job
    remaining: usize,
    /// workers spawned so far (ids 1..=workers; 0 is the submitter)
    workers: usize,
    /// a worker's closure panicked during the current job
    panicked: bool,
}

struct Pool {
    state: Mutex<PoolState>,
    /// wakes parked workers when `generation` bumps
    work_cv: Condvar,
    /// wakes the submitter when `remaining` hits zero
    done_cv: Condvar,
    /// serializes job submission: one job in flight at a time
    submit: Mutex<()>,
}

#[cfg(not(loom))]
fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(Pool::new)
}

thread_local! {
    // Set on pool workers (and on the submitter while it runs its own
    // partition) so nested kernel calls degrade to sequential instead
    // of deadlocking on the single job slot.
    static IN_POOL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

fn in_pool() -> bool {
    IN_POOL.with(|c| c.get())
}

/// The pool's state transitions, factored into instance methods so the
/// real worker/submitter paths and the loom models drive the *same*
/// code: `post_job` → (`next_job` → `finish_partition`)* → `drain`.
impl Pool {
    fn new() -> Pool {
        Pool {
            state: Mutex::new(PoolState {
                generation: 0,
                job: None,
                remaining: 0,
                workers: 0,
                panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
        }
    }

    /// Mutex poisoning is benign here (the state is plain counters),
    /// and a panicking kernel closure must not wedge every later
    /// kernel call — see the module-level invariants.
    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Submitter side, holding `submit`: publish `job` as the one in
    /// flight — bump the generation, set the worker countdown, wake
    /// the parked workers.
    fn post_job(&self, job: Job) {
        let mut st = self.lock_state();
        st.generation += 1;
        st.remaining = job.parts - 1;
        st.job = Some(job);
        if job.parts > 1 {
            self.work_cv.notify_all();
        }
    }

    /// Worker side: park until the generation moves past `last_gen`,
    /// then return the job slot.  `None` means the job already drained
    /// (and was cleared) before this non-participating worker got the
    /// lock — participants can't lag past completion, since completion
    /// waits on their decrement.
    fn next_job(&self, last_gen: &mut u64) -> Option<Job> {
        let mut st = self.lock_state();
        while st.generation == *last_gen {
            st = self.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        *last_gen = st.generation;
        st.job
    }

    /// Worker side, after the *last* use of `job.data`: record one
    /// completed partition (and whether its closure panicked), waking
    /// the submitter on the final decrement.
    fn finish_partition(&self, panicked: bool) {
        let mut st = self.lock_state();
        if panicked {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done_cv.notify_all();
        }
    }

    /// Submitter side (`WaitGuard::drop`): block until every
    /// participating worker has finished, then clear the job slot so
    /// late-waking non-participants observe `None`.
    fn drain(&self) {
        let mut st = self.lock_state();
        while st.remaining > 0 {
            st = self.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
    }

    /// Submitter side, after `drain`: take-and-reset the panic flag.
    fn take_panicked(&self) -> bool {
        std::mem::take(&mut self.lock_state().panicked)
    }
}

#[cfg(not(loom))]
impl Pool {
    /// Spawn parked workers until at least `needed` exist.  Only called
    /// by a submitter holding `submit`, i.e. with no job in flight.
    fn ensure_workers(&'static self, needed: usize) {
        let mut st = self.lock_state();
        while st.workers < needed {
            st.workers += 1;
            let id = st.workers;
            let start_gen = st.generation;
            crate::util::sync::thread::Builder::new()
                .name(format!("repro-par-{id}"))
                .spawn(move || worker_loop(pool(), id, start_gen))
                .expect("failed to spawn pool worker");
        }
    }
}

#[cfg(not(loom))]
fn worker_loop(pool: &'static Pool, id: usize, mut last_gen: u64) {
    IN_POOL.with(|c| c.set(true));
    loop {
        let Some(job) = pool.next_job(&mut last_gen) else { continue };
        if id >= job.parts {
            continue; // this job fans out narrower than the pool
        }
        let lo = id * job.chunk;
        let hi = ((id + 1) * job.chunk).min(job.len);
        // SAFETY: `data`/`call` form a live `&F` until the submitter's
        // completion barrier, which our `finish_partition` below
        // releases (the borrow-liveness invariant in the module docs).
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| unsafe {
            (job.call)(job.data, lo, hi)
        }));
        pool.finish_partition(r.is_err());
    }
}

unsafe fn call_shim<F: Fn(usize, usize) + Sync>(
    data: *const (), lo: usize, hi: usize,
) {
    // SAFETY: `data` was erased from a live `&F` by `run_pooled`, which
    // does not return until every partition has completed.
    let f = unsafe { &*(data as *const F) };
    f(lo, hi);
}

/// Blocks until the in-flight job fully drains — **also during an
/// unwind**, so the erased closure borrow can never dangle even if the
/// submitter's own partition panics.
struct WaitGuard<'a> {
    pool: &'a Pool,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.pool.drain();
    }
}

/// Fan `f` out over `parts` partitions of `0..len` on the pool; the
/// submitting thread runs partition 0 itself.  `parts >= 2`, `len >= 2`.
#[cfg(not(loom))]
fn run_pooled<F>(len: usize, parts: usize, f: &F)
where
    F: Fn(usize, usize) + Sync,
{
    let pool = pool();
    let _submit = pool.submit.lock().unwrap_or_else(|e| e.into_inner());
    let chunk = len.div_ceil(parts);
    let live = len.div_ceil(chunk); // partitions that are non-empty
    pool.ensure_workers(live - 1);
    pool.post_job(Job {
        data: f as *const F as *const (),
        call: call_shim::<F>,
        len,
        chunk,
        parts: live,
    });
    let wait = WaitGuard { pool };
    let was = IN_POOL.with(|c| c.replace(true));
    let r = std::panic::catch_unwind(AssertUnwindSafe(|| f(0, chunk.min(len))));
    IN_POOL.with(|c| c.set(was));
    drop(wait); // completion barrier (runs even when `r` is a panic)
    let worker_panicked = pool.take_panicked();
    if let Err(p) = r {
        std::panic::resume_unwind(p);
    }
    if worker_panicked {
        panic!("pool worker panicked during a parallel kernel");
    }
}

/// Under loom the partitioners degrade to sequential: the loom models
/// drive the `Pool` transitions directly (see `loom_tests`), and
/// fanning every kernel out inside a model would explode the state
/// space without checking anything new.
#[cfg(loom)]
fn run_pooled<F>(len: usize, _parts: usize, f: &F)
where
    F: Fn(usize, usize) + Sync,
{
    f(0, len);
}

// ---------------------------------------------------------------------
// Public partitioners
// ---------------------------------------------------------------------

/// Run `f(lo, hi)` over a static partition of `0..m` across the pool.
/// `f` must only touch output rows in its range (disjointness is the
/// caller's contract — identical to CUDA grid semantics).
pub fn for_row_blocks<F>(m: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let t = num_threads().min(m.max(1));
    if t <= 1 || m < ROW_PAR_MIN_ROWS || in_pool() {
        f(0, m);
        return;
    }
    run_pooled(m, t, &f);
}

/// Partitions the row-parallel `_out` dispatch: with the fast path on,
/// the cutoff weighs total work (`m * row_w`), so a short-but-wide
/// output (8 rows of vocab logits) still fans out; with it off, the
/// seed's row-count-only rule applies.
fn row_partitions(m: usize, row_w: usize) -> usize {
    let t = num_threads().min(m);
    if t <= 1 {
        return 1;
    }
    let parallel = if skinny_fast_path() {
        m >= 2 && m.saturating_mul(row_w) >= PAR_MIN_ROW_WORK
    } else {
        m >= ROW_PAR_MIN_ROWS
    };
    if parallel {
        t
    } else {
        1
    }
}

/// Like `for_row_blocks` but hands each block a disjoint mutable slice
/// of `out` (rows of width `row_w`).
pub fn for_row_blocks_out<F>(m: usize, row_w: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), m * row_w);
    let t = if in_pool() { 1 } else { row_partitions(m, row_w) };
    if t <= 1 {
        f(0, m, out);
        return;
    }
    let base = SendPtr::new(out.as_mut_ptr());
    let g = |lo: usize, hi: usize| {
        // SAFETY: row ranges are disjoint, so the subslices are too.
        let block = unsafe {
            std::slice::from_raw_parts_mut(
                base.get().add(lo * row_w),
                (hi - lo) * row_w,
            )
        };
        f(lo, hi, block);
    };
    run_pooled(m, t, &g);
}

/// Run `f(lo, hi)` over a static partition of the output-**column**
/// range `0..n` — the decode-shaped dual of `for_row_blocks`, for
/// kernels whose M is too skinny to split.  `col_w` is the approximate
/// work per column (used by the sequential cutoff); `f` must only
/// write output columns in its range.
pub fn for_col_blocks<F>(n: usize, col_w: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let t = num_threads().min(n.max(1));
    if t <= 1
        || n < 2
        || n.saturating_mul(col_w) < PAR_MIN_COL_WORK
        || in_pool()
    {
        f(0, n);
        return;
    }
    run_pooled(n, t, &f);
}

/// Serializes tests that flip the global `set_threads` /
/// `set_skinny_fast_path` knobs, so two determinism sweeps never
/// interleave their settings.  (Deliberately a `std` mutex even under
/// `--cfg loom`: it guards the *test harness*, not modeled code, and
/// loom mutexes cannot live outside `loom::model`.)
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;

    #[test]
    fn threads_per_shard_splits_the_total_budget() {
        assert_eq!(threads_per_shard(8, 1), 8);
        assert_eq!(threads_per_shard(8, 2), 4);
        assert_eq!(threads_per_shard(8, 3), 2); // remainder stays idle
        assert_eq!(threads_per_shard(1, 4), 1); // never below one
        assert_eq!(threads_per_shard(3, 4), 1); // budget < shards clamps
        assert_eq!(threads_per_shard(0, 2), 1);
        assert_eq!(threads_per_shard(8, 0), 8); // shards clamps to 1
    }

    #[test]
    fn covers_all_rows_exactly_once() {
        let hits = AtomicU64::new(0);
        for_row_blocks(1000, |lo, hi| {
            for _ in lo..hi {
                hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn covers_all_cols_exactly_once() {
        let hits = AtomicU64::new(0);
        // col_w large enough to clear the work cutoff => pooled
        for_col_blocks(1000, 1 << 20, |lo, hi| {
            for _ in lo..hi {
                hits.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn out_variant_writes_disjoint_slices() {
        let mut out = vec![0f32; 100 * 64];
        for_row_blocks_out(100, 64, &mut out, |lo, _hi, block| {
            for (i, row) in block.chunks_mut(64).enumerate() {
                row.fill((lo + i) as f32);
            }
        });
        for r in 0..100 {
            assert_eq!(out[r * 64], r as f32);
        }
    }

    #[test]
    fn small_inputs_run_serial() {
        let mut out = vec![0f32; 8];
        for_row_blocks_out(8, 1, &mut out, |lo, hi, block| {
            assert_eq!((lo, hi), (0, 8));
            block.fill(1.0);
        });
        assert!(out.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn tiny_col_jobs_run_serial() {
        // below the work cutoff: one invocation over the whole range
        let calls = AtomicU64::new(0);
        for_col_blocks(64, 1, |lo, hi| {
            assert_eq!((lo, hi), (0, 64));
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn set_threads_controls_partition_count() {
        let _g = test_guard();
        let orig = num_threads();
        set_threads(3);
        let parts = Mutex::new(Vec::new());
        for_row_blocks(90, |lo, hi| {
            parts.lock().unwrap().push((lo, hi));
        });
        set_threads(orig);
        let mut parts = parts.into_inner().unwrap();
        parts.sort_unstable();
        assert_eq!(parts, vec![(0, 30), (30, 60), (60, 90)]);
    }

    #[test]
    fn knobs_are_process_global_across_threads() {
        // set_threads / set_skinny_fast_path write shared atomics: a
        // change made here must be visible to kernels dispatched from
        // any other thread (which is why knob-sweeping tests serialize
        // on test_guard).
        let _g = test_guard();
        let orig_t = num_threads();
        let orig_f = skinny_fast_path();
        set_threads(3);
        set_skinny_fast_path(false);
        let seen = std::thread::spawn(|| {
            (num_threads(), skinny_fast_path(), skinny_col_dispatch(4))
        })
        .join()
        .unwrap();
        assert_eq!(seen, (3, false, false));
        set_skinny_fast_path(true);
        let seen =
            std::thread::spawn(|| skinny_col_dispatch(4)).join().unwrap();
        assert!(seen, "fast-path flip not visible across threads");
        set_threads(orig_t);
        set_skinny_fast_path(orig_f);
    }

    #[test]
    fn nested_calls_degrade_to_sequential_without_deadlock() {
        let _g = test_guard();
        let orig = num_threads();
        set_threads(4);
        let hits = AtomicU64::new(0);
        for_row_blocks(64, |lo, hi| {
            // a nested kernel from inside a pool job must not try to
            // take the single job slot again
            for_row_blocks(64, |ilo, ihi| {
                assert_eq!((ilo, ihi), (0, 64));
                hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
            });
        });
        set_threads(orig);
        assert_eq!(hits.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        // the serving engine + tests submit from many threads at once:
        // jobs serialize on the submit lock, every caller gets its own
        // complete result
        let sums: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        let sum = AtomicU64::new(0);
                        for_row_blocks(4096, |lo, hi| {
                            for i in lo..hi {
                                sum.fetch_add(i as u64, Ordering::Relaxed);
                            }
                        });
                        sum.load(Ordering::Relaxed)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let expect = (0u64..4096).sum::<u64>();
        assert!(sums.iter().all(|&s| s == expect), "{sums:?}");
    }

    #[test]
    fn concurrent_submitters_hammer_real_kernels() {
        // N caller threads × many iterations of real matmul kernels in
        // a tight loop: the submit lock must serialize cleanly under
        // contention — no deadlock, and every caller's result stays
        // bit-identical to its single-threaded golden even while other
        // callers keep the job slot churning.  (Bit-equality holds for
        // any thread count / dispatch shape, so a concurrently running
        // knob-sweeping test cannot perturb this one.)
        use crate::sparse::dense;
        use crate::tensor::Mat;
        use crate::util::rng::Pcg32;

        let mut rng = Pcg32::seeded(0x7a77);
        let skinny = Mat::randn(4, 96, 1.0, &mut rng); // column dispatch
        let wide = Mat::randn(64, 96, 1.0, &mut rng); // row dispatch
        let b = Mat::randn(96, 512, 1.0, &mut rng);
        let golden_skinny = dense::matmul(&skinny, &b).data;
        let golden_wide = dense::matmul(&wide, &b).data;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..8 {
                        assert_eq!(
                            dense::matmul(&skinny, &b).data,
                            golden_skinny
                        );
                        assert_eq!(dense::matmul(&wide, &b).data, golden_wide);
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let _g = test_guard();
        let orig = num_threads();
        set_threads(4);
        let r = std::panic::catch_unwind(|| {
            for_row_blocks(1024, |lo, _hi| {
                if lo > 0 {
                    panic!("boom in worker");
                }
            });
        });
        assert!(r.is_err(), "panic must propagate to the submitter");
        // the pool must still dispatch later jobs
        let hits = AtomicU64::new(0);
        for_row_blocks(1024, |lo, hi| {
            hits.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        set_threads(orig);
        assert_eq!(hits.load(Ordering::Relaxed), 1024);
    }
}

/// Loom model checks of the pool protocol.  Build + run with:
///
/// ```text
/// RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
///     cargo test --release --lib loom_
/// ```
///
/// Each test wraps one hairy transition of the real `Pool` methods in
/// `loom::model`, which executes the closure under **every** possible
/// interleaving of the participating threads (bounded by the
/// preemption budget) and additionally fails on deadlock or a missed
/// condvar wakeup.  The models can't use `worker_loop` itself — loom
/// requires every modeled thread to terminate — so workers run
/// [`worker_n`], the same `next_job`/`finish_partition` transitions
/// with a bounded job count.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use loom::sync::atomic::AtomicUsize as LoomUsize;
    use loom::sync::Arc;
    use loom::thread;

    /// Erase `f` into a job descriptor exactly the way `run_pooled`
    /// does.
    fn job_for<F: Fn(usize, usize) + Sync>(
        f: &F, len: usize, parts: usize,
    ) -> Job {
        let chunk = len.div_ceil(parts);
        Job {
            data: f as *const F as *const (),
            call: call_shim::<F>,
            len,
            chunk,
            parts,
        }
    }

    /// One worker servicing exactly `jobs` generation bumps — the
    /// bounded stand-in for `worker_loop`.
    fn worker_n(pool: &Pool, id: usize, mut last_gen: u64, jobs: usize) {
        for _ in 0..jobs {
            let Some(job) = pool.next_job(&mut last_gen) else {
                continue;
            };
            if id >= job.parts {
                continue;
            }
            let lo = id * job.chunk;
            let hi = ((id + 1) * job.chunk).min(job.len);
            // SAFETY: same contract as `worker_loop` — the submitter's
            // drain barrier keeps the erased `&F` alive until the
            // `finish_partition` below.
            unsafe { (job.call)(job.data, lo, hi) };
            pool.finish_partition(false);
        }
    }

    /// The full submitter protocol over an existing pool reference:
    /// post under the submit lock, run partition 0 inline, drain.
    fn submit_once(pool: &Pool, hits: &LoomUsize, len: usize) {
        let f = move |lo: usize, hi: usize| {
            hits.fetch_add(hi - lo, Ordering::Relaxed);
        };
        let job = job_for(&f, len, 2);
        let _submit = pool.submit.lock().unwrap();
        pool.post_job(job);
        let wait = WaitGuard { pool };
        f(0, job.chunk.min(job.len));
        drop(wait);
        assert!(!pool.take_panicked());
    }

    /// Scenario 1 — generation bump vs. parked-worker wakeup: however
    /// the post interleaves with the worker reaching its condvar wait,
    /// the worker must observe the new generation and its partition
    /// must land exactly once.
    #[test]
    fn loom_dispatch_wakes_parked_worker() {
        loom::model(|| {
            let pool = Arc::new(Pool::new());
            let hits = Arc::new(LoomUsize::new(0));
            let w = {
                let p = pool.clone();
                thread::spawn(move || worker_n(&p, 1, 0, 1))
            };
            submit_once(&pool, &hits, 8);
            assert_eq!(hits.load(Ordering::Relaxed), 8);
            w.join().unwrap();
        });
    }

    /// Scenario 2 — the non-participating worker: a pool wider than
    /// the job's fan-out must leave the extra worker contributing
    /// nothing, whether it wakes while the job is live (`id >= parts`)
    /// or after the drain cleared the slot (`job == None`) — and the
    /// countdown must not be double-decremented either way.
    #[test]
    fn loom_nonparticipant_sees_cleared_or_narrow_slot() {
        loom::model(|| {
            let pool = Arc::new(Pool::new());
            let hits = Arc::new(LoomUsize::new(0));
            let a = {
                let p = pool.clone();
                thread::spawn(move || worker_n(&p, 1, 0, 1))
            };
            let b = {
                let p = pool.clone();
                thread::spawn(move || worker_n(&p, 2, 0, 1))
            };
            submit_once(&pool, &hits, 4);
            assert_eq!(hits.load(Ordering::Relaxed), 4);
            {
                let st = pool.lock_state();
                assert!(st.job.is_none(), "drain must clear the slot");
                assert_eq!(st.remaining, 0);
            }
            a.join().unwrap();
            b.join().unwrap();
        });
    }

    /// Scenario 3 — `WaitGuard` draining during an unwind: the
    /// submitter posts and then *never runs its own partition*
    /// (modeling a panic before/inside it); dropping the guard alone
    /// must keep the erased closure borrow alive until the worker is
    /// done and leave the slot cleared.
    #[test]
    fn loom_waitguard_drains_on_unwind_path() {
        loom::model(|| {
            let pool = Arc::new(Pool::new());
            let hits = Arc::new(LoomUsize::new(0));
            let w = {
                let p = pool.clone();
                thread::spawn(move || worker_n(&p, 1, 0, 1))
            };
            {
                let h = hits.clone();
                let f = move |lo: usize, hi: usize| {
                    h.fetch_add(hi - lo, Ordering::Relaxed);
                };
                let job = job_for(&f, 6, 2);
                let _submit = pool.submit.lock().unwrap();
                pool.post_job(job);
                let wait = WaitGuard { pool: &*pool };
                drop(wait); // unwind path: no partition-0 call
            }
            // after the barrier the worker can no longer touch `f`,
            // and only its half [3, 6) ever ran
            assert_eq!(hits.load(Ordering::Relaxed), 3);
            let st = pool.lock_state();
            assert!(st.job.is_none());
            assert_eq!(st.remaining, 0);
            drop(st);
            w.join().unwrap();
        });
    }

    /// Scenario 4 — panic-flag propagation: a worker whose closure
    /// panicked reports through `finish_partition(true)`; the flag
    /// must reach the submitter after the barrier, exactly once, and
    /// the pool must accept the next job cleanly.
    #[test]
    fn loom_panic_flag_propagates_and_resets() {
        loom::model(|| {
            let pool = Arc::new(Pool::new());
            let w = {
                let p = pool.clone();
                thread::spawn(move || {
                    let mut last = 0;
                    let job = p.next_job(&mut last);
                    assert!(job.is_some(), "participant can't see None");
                    p.finish_partition(true); // closure "panicked"
                })
            };
            let f = |_lo: usize, _hi: usize| {};
            let job = job_for(&f, 2, 2);
            {
                let _submit = pool.submit.lock().unwrap();
                pool.post_job(job);
                let wait = WaitGuard { pool: &*pool };
                f(0, 1);
                drop(wait);
            }
            assert!(pool.take_panicked(), "worker panic must surface");
            assert!(!pool.take_panicked(), "flag is take-once");
            w.join().unwrap();
        });
    }

    /// Scenario 5 — two submitters racing one worker: the submit lock
    /// must serialize the jobs into distinct generations, the worker
    /// must service both, and each submitter must observe its own
    /// complete result.
    #[test]
    fn loom_submit_lock_serializes_two_submitters() {
        loom::model(|| {
            let pool = Arc::new(Pool::new());
            let hits_a = Arc::new(LoomUsize::new(0));
            let hits_b = Arc::new(LoomUsize::new(0));
            let w = {
                let p = pool.clone();
                thread::spawn(move || worker_n(&p, 1, 0, 2))
            };
            let b = {
                let p = pool.clone();
                let h = hits_b.clone();
                thread::spawn(move || submit_once(&p, &h, 4))
            };
            submit_once(&pool, &hits_a, 6);
            b.join().unwrap();
            w.join().unwrap();
            assert_eq!(hits_a.load(Ordering::Relaxed), 6);
            assert_eq!(hits_b.load(Ordering::Relaxed), 4);
        });
    }
}
