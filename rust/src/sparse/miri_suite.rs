//! Miri-targeted tiny-shape drives of every unsafe kernel path.
//!
//! ```text
//! MIRIFLAGS="-Zmiri-ignore-leaks -Zmiri-disable-isolation" \
//!     cargo +nightly miri test --lib -q -- miri_
//! ```
//!
//! Each test pushes one raw-pointer kernel family — dense column
//! blocks, TwELL gate tiles, the fused two-phase FFN, the routed
//! gather/accumulate, the hybrid pattern-masked pack — through the
//! *real* worker pool at 1 and 2 threads, on the smallest shapes that
//! clear the pool's work cutoffs (`PAR_MIN_ROW_WORK` /
//! `PAR_MIN_COL_WORK`), so the disjoint-range `SendPtr` writes
//! genuinely cross threads under the interpreter's Stacked Borrows and
//! data-race checks.  `-Zmiri-ignore-leaks` is required because pool
//! workers park forever by design and still exist at process exit.
//!
//! Compiled only under `cfg(miri)`: the regular suite already covers
//! these kernels at full size, where Miri would take hours.  Asserts
//! are bit-equality between the 1- and 2-thread runs (the module
//! contract), so no tolerance reasoning is needed here.

use crate::sparse::twell::gate_matmul_twell;
use crate::sparse::{dense, fused, par, route};
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// Run `body` under the knob guard at 1 then 2 threads, returning both
/// results for the caller's bit-equality assert.
fn sweep<T, F: FnMut() -> T>(mut body: F) -> (T, T) {
    let _g = par::test_guard();
    let orig = par::num_threads();
    par::set_threads(1);
    let a = body();
    par::set_threads(2);
    let b = body();
    par::set_threads(orig);
    (a, b)
}

#[test]
fn miri_dense_row_and_col_blocks() {
    let mut rng = Pcg32::seeded(1);
    let skinny = Mat::randn(2, 64, 1.0, &mut rng); // -> column blocks
    let b = Mat::randn(64, 256, 1.0, &mut rng);
    let wide = Mat::randn(32, 64, 1.0, &mut rng); // -> row blocks
    let wb = Mat::randn(64, 128, 1.0, &mut rng);
    let (s1, s2) = sweep(|| dense::matmul(&skinny, &b).data);
    assert_eq!(s1, s2);
    let (w1, w2) = sweep(|| dense::matmul(&wide, &wb).data);
    assert_eq!(w1, w2);
}

#[test]
fn miri_dense_matmul_nt_col_blocks() {
    let mut rng = Pcg32::seeded(2);
    let a = Mat::randn(2, 64, 1.0, &mut rng);
    let bt = Mat::randn(256, 64, 1.0, &mut rng);
    let (y1, y2) = sweep(|| dense::matmul_nt(&a, &bt).data);
    assert_eq!(y1, y2);
}

#[test]
fn miri_twell_gate_tiles() {
    let mut rng = Pcg32::seeded(3);
    let x = Mat::randn(2, 64, 1.0, &mut rng); // skinny -> tile-parallel
    let wg = Mat::randn(64, 256, 0.3, &mut rng);
    let xw = Mat::randn(32, 16, 1.0, &mut rng); // wide -> row-parallel
    let wgw = Mat::randn(16, 64, 0.3, &mut rng);
    let (t1, t2) = sweep(|| {
        let tw = gate_matmul_twell(&x, &wg, 32, 1);
        (tw.values.clone(), tw.indices.clone(), tw.nnz.clone())
    });
    assert_eq!(t1, t2);
    let (r1, r2) = sweep(|| {
        let tw = gate_matmul_twell(&xw, &wgw, 32, 1);
        (tw.values.clone(), tw.indices.clone(), tw.nnz.clone())
    });
    assert_eq!(r1, r2);
}

#[test]
fn miri_fused_two_phase_ffn() {
    let mut rng = Pcg32::seeded(4);
    let mut x = Mat::randn(2, 64, 1.0, &mut rng);
    for v in x.data.iter_mut() {
        *v = v.abs() + 0.05; // plenty of surviving gate activations
    }
    let wg = Mat::randn(64, 256, 0.3, &mut rng);
    let wu_t = Mat::randn(256, 64, 0.3, &mut rng);
    let wd = Mat::randn(256, 64, 0.3, &mut rng);
    let hg = gate_matmul_twell(&x, &wg, 32, 1);
    assert!(hg.total_nnz() > 0);
    let (y1, y2) = sweep(|| fused::fused_up_down(&x, &hg, &wu_t, &wd).data);
    assert_eq!(y1, y2);
}

#[test]
fn miri_routed_gather_and_accumulate() {
    let mut rng = Pcg32::seeded(5);
    let mut x = Mat::randn(2, 64, 1.0, &mut rng);
    for v in x.data.iter_mut() {
        *v = v.abs() + 0.05; // dense-ish union => gather goes parallel
    }
    let wg = Mat::randn(64, 512, 0.3, &mut rng);
    let wu_t = Mat::randn(512, 64, 0.3, &mut rng);
    let wd = Mat::randn(512, 64, 0.3, &mut rng);
    let hg = gate_matmul_twell(&x, &wg, 32, 1);
    let (r1, r2) = sweep(|| {
        let mut rs = route::RouteScratch::new(512, 64);
        let u = route::build_union(&hg, &mut rs);
        assert!(u > 0);
        let mut y = Mat::zeros(2, 64);
        route::routed_up_down_into(&x, &mut rs, &wu_t, &wd, &mut y);
        y.data
    });
    assert_eq!(r1, r2);
    // the routed path must stay bit-identical to the fused fallback
    let fused_y = fused::fused_up_down(&x, &hg, &wu_t, &wd);
    assert_eq!(r1, fused_y.data);
}

#[test]
fn miri_hybrid_pattern_masked_pack() {
    let mut rng = Pcg32::seeded(6);
    let mut pat = Mat::zeros(32, 48);
    for v in pat.data.iter_mut() {
        if rng.f32() < 0.15 {
            *v = rng.f32() + 0.01;
        }
    }
    for c in 0..40 {
        pat.data[5 * 48 + c] = 1.0; // heavy row -> dense tail branch
    }
    let hy = crate::sparse::hybrid::HybridMatrix::from_dense(&pat, 8, 4);
    assert!(hy.is_dense[5] && !hy.overflow);
    let a = Mat::randn(32, 12, 0.5, &mut rng);
    let b_t = Mat::randn(48, 12, 0.5, &mut rng);
    let (h1, h2) = sweep(|| {
        let out = hy.dense_to_hybrid_matmul(&a, &b_t);
        (out.ell_val.clone(), out.dense_tail.clone())
    });
    assert_eq!(h1, h2);
}
