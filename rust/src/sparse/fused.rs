//! Fused up + down projection from TwELL gate activations
//! (paper section 3.3, algorithm 2, eq. 3).
//!
//! For each input row m the kernel walks the packed tiles, and for every
//! stored non-zero n it computes the *implicit* h_u element
//! `u = x[m,:] . W_u[:,n]` in-register, scales the W_d row by
//! `h_v * u`, and accumulates into y[m,:].  Dense h_u / h are never
//! materialized.  W_u is consumed in transposed layout (N x K) so the
//! gathered column is a contiguous row — the same trick as the CUDA
//! kernel (appendix A.1: "the up projection weight matrix is stored in
//! transposed format" for coalescing).
//!
//! One CPU thread block of rows plays the role of the paper's grid of
//! single-warp CTAs; the per-row independence that lets the GPU hide
//! uneven-sparsity latency is what makes the static row split safe here.
//!
//! This kernel is also the **fallback branch** of the batch-contextual
//! decode router (`sparse::route`): the routed union-gather kernel
//! reproduces this kernel's per-element accumulation order exactly
//! (same `dense::dot` for the implicit h_u, same `v * u` coefficient,
//! same ascending-column `axpy` walk), so the router can switch
//! between the two per step without changing a bit of the output.

use crate::sparse::twell::TwellMatrix;
use crate::sparse::{dense, par};
use crate::tensor::Mat;

/// y = ((h_g in TwELL) ⊙ (x @ W_u)) @ W_d, fused (algorithm 2).
///
/// * `wu_t` — W_u transposed, (N, K) row-major.
/// * `wd`   — W_d, (N, K) row-major.
pub fn fused_up_down(
    x: &Mat, hg: &TwellMatrix, wu_t: &Mat, wd: &Mat,
) -> Mat {
    let mut y = Mat::zeros(x.rows, x.cols);
    let mut coef = Vec::new();
    fused_up_down_into(x, hg, wu_t, wd, &mut y, &mut coef);
    y
}

/// `fused_up_down` into a caller-owned output plus a coefficient
/// scratch (one slot per packed non-zero; the decode scratch owns
/// both, so the hot loop never allocates).
///
/// Large M runs the row-block kernel.  Skinny M runs in two phases so
/// the pool still has parallel work: **(1)** the implicit-h_u
/// coefficients `v * (x[m,:] . W_u[:,n])` parallel over *tiles* (each
/// tile's packed region is written by exactly one worker), **(2)** the
/// `y += coef * W_d[n,:]` accumulation parallel over *output columns*
/// (each worker owns a disjoint column range of every row).  Per
/// output element both shapes execute the same tile-order accumulation
/// with the same coefficients, so row dispatch, column dispatch, and
/// any thread count produce bit-identical y.
pub fn fused_up_down_into(
    x: &Mat, hg: &TwellMatrix, wu_t: &Mat, wd: &Mat, y: &mut Mat,
    coef: &mut Vec<f32>,
) {
    let (m, k) = (x.rows, x.cols);
    assert_eq!(hg.m, m);
    assert_eq!(wu_t.rows, hg.n);
    assert_eq!(wu_t.cols, k);
    assert_eq!(wd.rows, hg.n);
    assert_eq!(wd.cols, k);
    assert_eq!((y.rows, y.cols), (m, k));
    let slots = hg.slots();
    let pc = hg.packed_cols();
    let n_tiles = hg.n_tiles();
    y.data.fill(0.0);
    if par::skinny_fast_path()
        && m < par::ROW_PAR_MIN_ROWS
        && par::num_threads() > 1
    {
        // ---- phase 1: coefficients, tile-parallel ----
        coef.resize(m * pc, 0.0); // slots past a tile's nnz: never read
        let coef_ptr = par::SendPtr::new(coef.as_mut_ptr());
        par::for_col_blocks(n_tiles, m * k * slots, |tlo, thi| {
            for r in 0..m {
                let xrow = &x.data[r * k..(r + 1) * k];
                for t in tlo..thi {
                    let z = hg.nnz[r * n_tiles + t] as usize;
                    let base = r * pc + t * slots;
                    for c in 0..z {
                        let n = hg.indices[base + c] as usize;
                        // implicit h_u element (eq. 3 middle factor)
                        let u = dense::dot(xrow, wu_t.row(n));
                        // SAFETY: slot `base + c` lies in tile `t`'s
                        // packed region, tiles partition `coef`, and
                        // each worker owns the disjoint tile range
                        // [tlo, thi); `coef` (resized above) outlives
                        // the pool barrier inside `for_col_blocks`.
                        unsafe {
                            *coef_ptr.get().add(base + c) =
                                hg.values[base + c] * u;
                        }
                    }
                }
            }
        });
        // ---- phase 2: accumulate, column-parallel ----
        let nnz_total = hg.total_nnz() as usize;
        let y_ptr = par::SendPtr::new(y.data.as_mut_ptr());
        let coef = &coef[..];
        par::for_col_blocks(k, nnz_total.max(1), |lo, hi| {
            for r in 0..m {
                // SAFETY: each worker owns the disjoint output-column
                // range [lo, hi) of every row, so these subslices never
                // overlap across workers; `y.data` outlives the pool
                // barrier inside `for_col_blocks`.
                let yrow = unsafe {
                    std::slice::from_raw_parts_mut(
                        y_ptr.get().add(r * k + lo),
                        hi - lo,
                    )
                };
                for t in 0..n_tiles {
                    let z = hg.nnz[r * n_tiles + t] as usize;
                    let base = r * pc + t * slots;
                    for c in 0..z {
                        let n = hg.indices[base + c] as usize;
                        dense::axpy(
                            coef[base + c],
                            &wd.row(n)[lo..hi],
                            yrow,
                        );
                    }
                }
            }
        });
    } else {
        par::for_row_blocks_out(m, k, &mut y.data, |lo, hi, out| {
            for r in lo..hi {
                let xrow = &x.data[r * k..(r + 1) * k];
                let yrow = &mut out[(r - lo) * k..(r - lo + 1) * k];
                for t in 0..n_tiles {
                    let z = hg.nnz[r * n_tiles + t] as usize;
                    let base = r * pc + t * slots;
                    for c in 0..z {
                        let n = hg.indices[base + c] as usize;
                        let v = hg.values[base + c];
                        // implicit h_u element (eq. 3 middle factor)
                        let u = dense::dot(xrow, wu_t.row(n));
                        dense::axpy(v * u, wd.row(n), yrow);
                    }
                }
            }
        });
    }
}

/// Non-gated variant (appendix A.1, listing 3): y = (h_u in TwELL) @ W_d.
pub fn down_from_twell(hu: &TwellMatrix, wd: &Mat) -> Mat {
    let m = hu.m;
    let k = wd.cols;
    assert_eq!(wd.rows, hu.n);
    let slots = hu.slots();
    let pc = hu.packed_cols();
    let n_tiles = hu.n_tiles();
    let mut y = Mat::zeros(m, k);
    par::for_row_blocks_out(m, k, &mut y.data, |lo, hi, out| {
        for r in lo..hi {
            let yrow = &mut out[(r - lo) * k..(r - lo + 1) * k];
            for t in 0..n_tiles {
                let z = hu.nnz[r * n_tiles + t] as usize;
                let base = r * pc + t * slots;
                for c in 0..z {
                    let n = hu.indices[base + c] as usize;
                    dense::axpy(hu.values[base + c], wd.row(n), yrow);
                }
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::twell::gate_matmul_twell;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    fn setup(m: usize, k: usize, n: usize, bias: f32, seed: u64)
        -> (Mat, Mat, Mat, Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let mut x = Mat::randn(m, k, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.05; // positive inputs; see twell.rs tests
        }
        let mut wg = Mat::randn(k, n, 0.3, &mut rng);
        for v in wg.data.iter_mut() {
            *v -= bias / k as f32;
        }
        let wu = Mat::randn(k, n, 0.3, &mut rng);
        let wd = Mat::randn(n, k, 0.3, &mut rng);
        let wu_t = wu.transpose();
        (x, wg, wu, wu_t, wd)
    }

    #[test]
    fn fused_matches_dense_ffn_without_overflow() {
        let (x, wg, wu, wu_t, wd) = setup(24, 16, 64, 0.0, 1);
        let hg = gate_matmul_twell(&x, &wg, 32, 1);
        assert!(!hg.overflow);
        let y = fused_up_down(&x, &hg, &wu_t, &wd);
        let y_dense = dense::gated_ffn(&x, &wg, &wu, &wd);
        assert!(y.rel_err(&y_dense) < 1e-4, "{}", y.rel_err(&y_dense));
    }

    #[test]
    fn down_matches_dense_nongated() {
        let (x, wu2, _, _, wd) = setup(16, 16, 64, 0.0, 2);
        let hu = gate_matmul_twell(&x, &wu2, 32, 1);
        let y = down_from_twell(&hu, &wd);
        let y_dense = dense::nongated_ffn(&x, &wu2, &wd);
        assert!(y.rel_err(&y_dense) < 1e-4);
    }

    #[test]
    fn zero_gate_rows_produce_zero_output() {
        let (x, mut wg, _, wu_t, wd) = setup(8, 8, 32, 0.0, 3);
        for v in wg.data.iter_mut() {
            *v = -v.abs() - 0.1; // gate always negative => empty TwELL
        }
        let hg = gate_matmul_twell(&x, &wg, 32, 4);
        assert_eq!(hg.total_nnz(), 0);
        let y = fused_up_down(&x, &hg, &wu_t, &wd);
        assert!(y.data.iter().all(|&v| v == 0.0));
    }

    /// The fused kernel's decode shapes must be bit-exact across
    /// thread counts and across row vs two-phase column dispatch.
    #[test]
    fn fused_bit_exact_across_threads_and_dispatch() {
        let _g = par::test_guard();
        let orig = par::num_threads();
        // m < 32, with enough columns/nnz that both phases clear their
        // parallel work cutoffs when the fast path is on
        let (x, wg, _, wu_t, wd) = setup(4, 128, 512, 0.0, 21);
        let hg = gate_matmul_twell(&x, &wg, 32, 1);
        let mut runs = Vec::new();
        for &threads in &[1usize, 4] {
            for &fast in &[false, true] {
                par::set_threads(threads);
                par::set_skinny_fast_path(fast);
                runs.push(fused_up_down(&x, &hg, &wu_t, &wd).data);
            }
        }
        par::set_threads(orig);
        par::set_skinny_fast_path(true);
        for (i, y) in runs[1..].iter().enumerate() {
            assert_eq!(y, &runs[0], "run {} diverged bitwise", i + 1);
        }
    }

    #[test]
    fn into_variant_reuses_scratch_cleanly() {
        // a big batch then a small one through the same y/coef scratch
        // must match a fresh small-batch run exactly
        let (xb, wgb, _, wu_tb, wdb) = setup(24, 16, 64, 0.0, 22);
        let hgb = gate_matmul_twell(&xb, &wgb, 32, 1);
        let mut y = Mat::zeros(24, 16);
        let mut coef = Vec::new();
        fused_up_down_into(&xb, &hgb, &wu_tb, &wdb, &mut y, &mut coef);
        let (xs, wgs, _, wu_ts, wds) = setup(2, 16, 64, 0.0, 23);
        let hgs = gate_matmul_twell(&xs, &wgs, 32, 1);
        y.set_rows(2);
        fused_up_down_into(&xs, &hgs, &wu_ts, &wds, &mut y, &mut coef);
        let fresh = fused_up_down(&xs, &hgs, &wu_ts, &wds);
        assert_eq!(y.data, fresh.data);
    }

    #[test]
    fn prop_fused_equals_dense_over_shapes_and_sparsity() {
        check("fused twell ffn == dense ffn", 20, 11, |g: &mut Gen| {
            let m = 4 * g.usize_in(1, 8);
            let k = g.usize_in(4, 24);
            let n = 32 * g.usize_in(1, 3);
            let bias = g.f32_in(0.0, 8.0);
            let (x, wg, wu, wu_t, wd) = setup(m, k, n, bias, g.rng.next_u64());
            let hg = gate_matmul_twell(&x, &wg, 32, 1);
            let y = fused_up_down(&x, &hg, &wu_t, &wd);
            let y_dense = dense::gated_ffn(&x, &wg, &wu, &wd);
            let err = y.rel_err(&y_dense);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("rel err {err} at ({m},{k},{n},{bias})"))
            }
        });
    }
}
