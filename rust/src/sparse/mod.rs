//! The paper's sparse kernels, re-realized as multithreaded CPU kernels
//! (DESIGN.md section 1 "Hardware adaptation").
//!
//! * [`dense`]  — tiled dense matmul baseline (the cuBLAS stand-in).
//! * [`ell`]    — classic ELLPACK format + SpMM (paper section 3.1).
//! * [`twell`]  — Tile-wise ELLPACK: the pack happens in the matmul
//!                epilogue, exactly like algorithm 1.
//! * [`fused`]  — fused up+down projection from TwELL (algorithm 2).
//! * [`hybrid`] — the ELL+dense training format with dense↔hybrid
//!                matmuls, transpose and L1 injection (algorithm 3,
//!                listings 4-7).
//! * [`ffn`]    — whole feed-forward blocks (inference pipelines and the
//!                training step with the paper's eq. 4 backward).
//! * [`par`]    — persistent worker pool with row- and column-block
//!                partitioners (rayon is not vendored); skinny decode
//!                batches dispatch column-parallel.
//! * [`route`]  — batch-contextual sparsity routing: union-gathered
//!                skinny FFN for batched decode (Polar-Sparsity-style
//!                batch-granular dispatch).
//!
//! # Decode dispatch decision tree
//!
//! A decode-step FFN call (`ffn::forward_backend_step_into`) picks its
//! kernel shape in two stages, both observable through the
//! [`route::RouteStats`] counters:
//!
//! 1. **Routing (TwELL backend, pure-decode feeds only).**  With
//!    routing enabled (`ServePolicy.route_density > 0`), the packed
//!    gate's batch union of active FFN columns is measured every step:
//!    * `union / d_ff <= route_density` → **routed**: gather the union
//!      slice of `W_u^T`/`W_d` and run dense skinny GEMMs over it
//!      (`route::routed_up_down_into`).
//!    * otherwise → **fallback**: the fused TwELL kernel
//!      (`fused::fused_up_down_into`).  Mixed feeds (a ragged prefill
//!      span alongside decode slots) also land here — prefill rows
//!      densify the union.
//!    Both branches are bit-identical, so the threshold only moves
//!    throughput, never a logit bit.
//! 2. **Partitioning (every kernel).**  Each kernel then splits its
//!    output across the worker pool:
//!    * batch `m >= 32` (or the skinny fast path off) → **row**-block
//!      partition, the prefill/training shape;
//!    * `m < 32` with the fast path on and `> 1` thread (and enough
//!      work to clear the pool cutoffs) → **column**-block partition:
//!      every worker walks the same few rows, each owning a disjoint
//!      output-column range.
//!
//! Every leaf computes each output element with the same sequential
//! accumulation order, so the whole tree is bit-exact for any thread
//! count, any dispatch shape, and any routing threshold.

pub mod dense;
pub mod ell;
pub mod ffn;
pub mod fused;
pub mod hybrid;
#[cfg(all(test, miri))]
mod miri_suite;
pub mod par;
pub mod route;
pub mod twell;
