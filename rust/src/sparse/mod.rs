//! The paper's sparse kernels, re-realized as multithreaded CPU kernels
//! (DESIGN.md section 1 "Hardware adaptation").
//!
//! * [`dense`]  — tiled dense matmul baseline (the cuBLAS stand-in).
//! * [`ell`]    — classic ELLPACK format + SpMM (paper section 3.1).
//! * [`twell`]  — Tile-wise ELLPACK: the pack happens in the matmul
//!                epilogue, exactly like algorithm 1.
//! * [`fused`]  — fused up+down projection from TwELL (algorithm 2).
//! * [`hybrid`] — the ELL+dense training format with dense↔hybrid
//!                matmuls, transpose and L1 injection (algorithm 3,
//!                listings 4-7).
//! * [`ffn`]    — whole feed-forward blocks (inference pipelines and the
//!                training step with the paper's eq. 4 backward).
//! * [`par`]    — persistent worker pool with row- and column-block
//!                partitioners (rayon is not vendored); skinny decode
//!                batches dispatch column-parallel.

pub mod dense;
pub mod ell;
pub mod ffn;
pub mod fused;
pub mod hybrid;
pub mod par;
pub mod twell;
