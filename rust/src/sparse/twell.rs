//! TwELL — Tile-wise ELLPACK (paper section 3.2, algorithm 1).
//!
//! The format: columns are grouped in tiles of width `tile_n`; within each
//! tile, the non-zero values and their global column indices are packed at
//! the start of a `tile_n / comp`-slot region, and the per-tile non-zero
//! count is stored separately (so no padding sentinel is ever read).
//!
//! The defining property vs classic ELL is *materialization in the matmul
//! epilogue*: the pack needs only the output tile that the matmul just
//! produced (no cross-CTA view of the row), so `gate_matmul_twell`
//! performs `ReLU(x @ Wg)` and emits TwELL directly, tile by tile —
//! exactly the fusion of algorithm 1, with the CPU cache-block playing
//! the role of the CTA tile.

use crate::sparse::{dense, par};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct TwellMatrix {
    pub m: usize,
    pub n: usize,
    pub tile_n: usize,
    pub comp: usize,
    /// packed non-zero values, (m, n / comp)
    pub values: Vec<f32>,
    /// packed global column indices, (m, n / comp)
    pub indices: Vec<u16>,
    /// per-tile non-zero counts, (m, n_tiles)
    pub nnz: Vec<u16>,
    /// true iff some tile had more non-zeros than slots (drop-and-flag,
    /// appendix B.2.1)
    pub overflow: bool,
}

impl TwellMatrix {
    pub fn n_tiles(&self) -> usize {
        self.n / self.tile_n
    }

    pub fn slots(&self) -> usize {
        self.tile_n / self.comp
    }

    pub fn packed_cols(&self) -> usize {
        self.n / self.comp
    }

    /// Total non-zeros stored.
    pub fn total_nnz(&self) -> u64 {
        self.nnz.iter().map(|&z| z as u64).sum()
    }

    /// Average non-zeros per row (the paper's headline statistic).
    pub fn avg_nnz_per_row(&self) -> f64 {
        self.total_nnz() as f64 / self.m as f64
    }

    /// Storage footprint in bytes (figure 1 accounting: packed 32-bit
    /// value+index words plus 16-bit counts).
    pub fn bytes(&self) -> u64 {
        (self.values.len() * 4 + self.indices.len() * 2 + self.nnz.len() * 2)
            as u64
    }

    /// Iterate row `r`'s packed (global column, value) entries.
    ///
    /// Entries come out in **ascending global-column order** — tiles
    /// ascending, slots within a tile ascending — which is exactly the
    /// order the fused kernel accumulates in.  `sparse::route` walks
    /// this to build its sorted batch union, so routed and fused paths
    /// share one accumulation order (the bit-exactness invariant).
    pub fn row_entries(
        &self,
        r: usize,
    ) -> impl Iterator<Item = (u16, f32)> + '_ {
        let n_tiles = self.n_tiles();
        let slots = self.slots();
        let pc = self.packed_cols();
        (0..n_tiles).flat_map(move |t| {
            let z = self.nnz[r * n_tiles + t] as usize;
            let base = r * pc + t * slots;
            (0..z).map(move |c| (self.indices[base + c], self.values[base + c]))
        })
    }

    /// Scatter back to dense (tests / format conversions).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.m, self.n);
        let slots = self.slots();
        let pc = self.packed_cols();
        for r in 0..self.m {
            for t in 0..self.n_tiles() {
                let z = self.nnz[r * self.n_tiles() + t] as usize;
                for c in 0..z {
                    let j = r * pc + t * slots + c;
                    out.data[r * self.n + self.indices[j] as usize] =
                        self.values[j];
                }
            }
        }
        out
    }

    /// An empty, zero-filled TwELL container for `m` rows of `n`
    /// columns.  The decode scratch allocates one at its maximum batch
    /// size; `gate_matmul_twell_into` reshapes it per step within the
    /// backing vectors' high-water marks — allocation-free.
    pub fn with_capacity(
        m: usize, n: usize, tile_n: usize, comp: usize,
    ) -> TwellMatrix {
        assert_eq!(n % tile_n, 0);
        assert_eq!(tile_n % comp, 0);
        TwellMatrix {
            m,
            n,
            tile_n,
            comp,
            values: vec![0.0; m * (n / comp)],
            indices: vec![0; m * (n / comp)],
            nnz: vec![0; m * (n / tile_n)],
            overflow: false,
        }
    }

    /// Pack an existing dense matrix (used by tests and the ELL
    /// comparison bench; the hot path uses `gate_matmul_twell`).
    pub fn from_dense(h: &Mat, tile_n: usize, comp: usize) -> TwellMatrix {
        assert_eq!(h.cols % tile_n, 0);
        assert_eq!(tile_n % comp, 0);
        let (m, n) = (h.rows, h.cols);
        let n_tiles = n / tile_n;
        let slots = tile_n / comp;
        let pc = n / comp;
        let mut tw = TwellMatrix {
            m,
            n,
            tile_n,
            comp,
            values: vec![0.0; m * pc],
            indices: vec![0; m * pc],
            nnz: vec![0; m * n_tiles],
            overflow: false,
        };
        for r in 0..m {
            for t in 0..n_tiles {
                let mut z = 0usize;
                for c in 0..tile_n {
                    let v = h.data[r * n + t * tile_n + c];
                    if v > 0.0 {
                        if z < slots {
                            let j = r * pc + t * slots + z;
                            tw.values[j] = v;
                            tw.indices[j] = (t * tile_n + c) as u16;
                        } else {
                            tw.overflow = true;
                        }
                        z += 1;
                    }
                }
                tw.nnz[r * n_tiles + t] = z.min(slots) as u16;
            }
        }
        tw
    }
}

/// Algorithm 1: `h_g = ReLU(x @ Wg)` materialized directly in TwELL.
///
/// The matmul runs tile-by-tile over the output; each finished
/// (row-block, tile_n) tile is packed in the epilogue before moving on —
/// no second pass over a dense h_g ever happens (the whole point of the
/// format, section 3.2).
pub fn gate_matmul_twell(
    x: &Mat, wg: &Mat, tile_n: usize, comp: usize,
) -> TwellMatrix {
    let mut out = TwellMatrix::with_capacity(x.rows, wg.cols, tile_n, comp);
    gate_matmul_twell_into(x, wg, tile_n, comp, &mut out);
    out
}

/// `gate_matmul_twell` into a caller-owned container (reshaped here;
/// allocation-free once the container has seen its maximum batch).
///
/// Dispatch: row blocks when M is large; for skinny decode batches the
/// **tiles** parallelize instead — tiles are independent by
/// construction (the pack epilogue only ever touches its own tile's
/// value/index/count region), so the column split has no cross-thread
/// writes, and `fill_tile` is the single code path both dispatches
/// run, which keeps them bit-exact for any thread count.
pub fn gate_matmul_twell_into(
    x: &Mat, wg: &Mat, tile_n: usize, comp: usize, out: &mut TwellMatrix,
) {
    let (m, k, n) = (x.rows, x.cols, wg.cols);
    assert_eq!(x.cols, wg.rows);
    assert_eq!(n % tile_n, 0);
    assert_eq!(tile_n % comp, 0);
    assert!(n <= u16::MAX as usize + 1, "u16 column indices");
    let n_tiles = n / tile_n;
    let slots = tile_n / comp;
    let pc = n / comp;
    out.m = m;
    out.n = n;
    out.tile_n = tile_n;
    out.comp = comp;
    out.values.resize(m * pc, 0.0);
    out.values.fill(0.0);
    out.indices.resize(m * pc, 0);
    out.indices.fill(0);
    out.nnz.resize(m * n_tiles, 0);
    let overflow = std::sync::atomic::AtomicBool::new(false);

    let values_ptr = par::SendPtr::new(out.values.as_mut_ptr());
    let indices_ptr = par::SendPtr::new(out.indices.as_mut_ptr());
    let nnz_ptr = par::SendPtr::new(out.nnz.as_mut_ptr());
    if par::use_col_dispatch(m, n_tiles, m * k * tile_n) {
        // skinny path: every worker owns a disjoint tile range and
        // walks all m rows
        par::for_col_blocks(n_tiles, m * k * tile_n, |tlo, thi| {
            let mut tile = vec![0f32; tile_n];
            for r in 0..m {
                let xrow = &x.data[r * k..(r + 1) * k];
                for t in tlo..thi {
                    let (z, over) = fill_tile(
                        xrow, wg, t, &mut tile, slots,
                        r * pc + t * slots, &values_ptr, &indices_ptr,
                    );
                    // SAFETY: (r, t) is unique to this worker's range
                    unsafe {
                        *nnz_ptr.get().add(r * n_tiles + t) = z;
                    }
                    if over {
                        overflow
                            .store(true, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            }
        });
    } else {
        // parallel over row blocks; each block owns its rows of all
        // three output arrays
        par::for_row_blocks(m, |lo, hi| {
            let mut tile = vec![0f32; tile_n];
            for r in lo..hi {
                let xrow = &x.data[r * k..(r + 1) * k];
                for t in 0..n_tiles {
                    let (z, over) = fill_tile(
                        xrow, wg, t, &mut tile, slots,
                        r * pc + t * slots, &values_ptr, &indices_ptr,
                    );
                    // SAFETY: row range is exclusive to this block
                    unsafe {
                        *nnz_ptr.get().add(r * n_tiles + t) = z;
                    }
                    if over {
                        overflow
                            .store(true, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            }
        });
    }
    out.overflow = overflow.load(std::sync::atomic::Ordering::Relaxed);
}

/// Matmul + ReLU + pack for one (row, tile) — algorithm 1 lines 6-18.
/// The one code path both dispatch shapes execute (bit-exactness).
/// Packs into `[j0, j0 + slots)` of the value/index arrays; returns
/// the tile's stored count and whether it spilled (drop-and-flag).
#[inline]
fn fill_tile(
    xrow: &[f32], wg: &Mat, t: usize, tile: &mut [f32], slots: usize,
    j0: usize, values: &par::SendPtr<f32>, indices: &par::SendPtr<u16>,
) -> (u16, bool) {
    let tile_n = tile.len();
    let n = wg.cols;
    let n0 = t * tile_n;
    // --- matmul for this tile (k-major AXPY over the tile) ---
    tile.fill(0.0);
    for (kk, &xv) in xrow.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        dense::axpy(xv, &wg.data[kk * n + n0..kk * n + n0 + tile_n], tile);
    }
    // --- epilogue: ReLU + TwELL pack ---
    let mut z = 0usize;
    let mut over = false;
    for (c, &s) in tile.iter().enumerate() {
        if s > 0.0 {
            if z < slots {
                // SAFETY: this (row, tile) region belongs to exactly
                // one worker on either dispatch shape
                unsafe {
                    *values.get().add(j0 + z) = s;
                    *indices.get().add(j0 + z) = (n0 + c) as u16;
                }
            } else {
                over = true;
            }
            z += 1;
        }
    }
    (z.min(slots) as u16, over)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    /// Positive inputs + negatively shifted gate weights give a
    /// controllable expected sparsity: E[x.wg_col] = -bias * E[x].
    fn sparse_gate(m: usize, k: usize, n: usize, bias: f32, seed: u64)
        -> (Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let mut x = Mat::randn(m, k, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.05;
        }
        let mut wg = Mat::randn(k, n, 0.3, &mut rng);
        for v in wg.data.iter_mut() {
            *v -= bias / k as f32;
        }
        (x, wg)
    }

    #[test]
    fn fused_pack_equals_pack_of_dense_matmul() {
        let (x, wg) = sparse_gate(24, 16, 64, 0.0, 1);
        let tw = gate_matmul_twell(&x, &wg, 32, 2);
        let hg = dense::matmul_relu(&x, &wg);
        let tw_ref = TwellMatrix::from_dense(&hg, 32, 2);
        assert_eq!(tw.indices, tw_ref.indices);
        assert_eq!(tw.nnz, tw_ref.nnz);
        for (a, b) in tw.values.iter().zip(&tw_ref.values) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn roundtrip_without_overflow() {
        let (x, wg) = sparse_gate(16, 8, 64, 0.0, 2);
        let tw = gate_matmul_twell(&x, &wg, 32, 1); // comp=1: lossless
        assert!(!tw.overflow);
        let hg = dense::matmul_relu(&x, &wg);
        assert!(tw.to_dense().max_abs_diff(&hg) < 1e-4);
    }

    #[test]
    fn overflow_flag_set_when_tiles_spill() {
        let mut rng = Pcg32::seeded(3);
        let mut x = Mat::randn(8, 8, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.5; // all-positive input
        }
        let mut wg = Mat::randn(8, 32, 0.3, &mut rng);
        for v in wg.data.iter_mut() {
            *v = v.abs() + 0.1; // all-positive weights => dense gate
        }
        let tw = gate_matmul_twell(&x, &wg, 32, 8);
        assert!(tw.overflow);
        assert!(tw.nnz.iter().all(|&z| z as usize <= 4));
    }

    #[test]
    fn nnz_statistics() {
        let (x, wg) = sparse_gate(64, 16, 128, 12.0, 4);
        let tw = gate_matmul_twell(&x, &wg, 32, 1);
        let hg = dense::matmul_relu(&x, &wg);
        assert_eq!(tw.total_nnz(), hg.nnz_positive() as u64);
        assert!(tw.avg_nnz_per_row() < 128.0 * 0.5);
    }

    #[test]
    fn storage_smaller_than_dense_at_comp() {
        let (x, wg) = sparse_gate(64, 16, 128, 8.0, 5);
        let tw = gate_matmul_twell(&x, &wg, 32, 4);
        assert!(tw.bytes() < (64 * 128 * 4) as u64 / 2);
    }

    /// Skinny batches must produce the identical pack — values,
    /// indices, counts, overflow — no matter the thread count and no
    /// matter whether rows or tiles were split across workers.
    #[test]
    fn gate_pack_bit_exact_across_threads_and_dispatch() {
        let _g = par::test_guard();
        let orig = par::num_threads();
        // m < 32 and n_tiles * m * k * tile_n well past the column
        // work cutoff, so the fast path genuinely goes tile-parallel
        let (x, wg) = sparse_gate(4, 64, 512, 4.0, 9);
        let mut runs = Vec::new();
        for &threads in &[1usize, 4] {
            for &fast in &[false, true] {
                par::set_threads(threads);
                par::set_skinny_fast_path(fast);
                runs.push(gate_matmul_twell(&x, &wg, 32, 2));
            }
        }
        par::set_threads(orig);
        par::set_skinny_fast_path(true);
        for tw in &runs[1..] {
            assert_eq!(tw.values, runs[0].values, "values diverged");
            assert_eq!(tw.indices, runs[0].indices, "indices diverged");
            assert_eq!(tw.nnz, runs[0].nnz, "counts diverged");
            assert_eq!(tw.overflow, runs[0].overflow);
        }
    }

    #[test]
    fn into_variant_reuses_a_larger_container_cleanly() {
        // pack a big batch, then a small one into the same container:
        // the small result must be identical to a fresh pack (no stale
        // values/indices/counts leaking through)
        let (xb, wgb) = sparse_gate(24, 16, 64, 0.0, 12);
        let mut scratch = gate_matmul_twell(&xb, &wgb, 32, 2);
        let (xs, wgs) = sparse_gate(3, 16, 64, 0.0, 13);
        gate_matmul_twell_into(&xs, &wgs, 32, 2, &mut scratch);
        let fresh = gate_matmul_twell(&xs, &wgs, 32, 2);
        assert_eq!(scratch.m, 3);
        assert_eq!(scratch.values, fresh.values);
        assert_eq!(scratch.indices, fresh.indices);
        assert_eq!(scratch.nnz, fresh.nnz);
        assert_eq!(scratch.overflow, fresh.overflow);
    }

    #[test]
    fn prop_pack_matches_reference_pack() {
        check("twell fused pack == from_dense", 25, 7, |g: &mut Gen| {
            let m = 8 * g.usize_in(1, 4);
            let k = g.usize_in(4, 32);
            let tiles = g.usize_in(1, 3);
            let tile_n = *g.choose(&[16usize, 32]);
            let comp = *g.choose(&[1usize, 2, 4]);
            let n = tiles * tile_n;
            let bias = g.f32_in(0.0, 10.0);
            let (x, wg) = sparse_gate(m, k, n, bias, g.rng.next_u64());
            let tw = gate_matmul_twell(&x, &wg, tile_n, comp);
            let tw_ref =
                TwellMatrix::from_dense(&dense::matmul_relu(&x, &wg), tile_n,
                                        comp);
            if tw.indices != tw_ref.indices || tw.nnz != tw_ref.nnz {
                return Err(format!("index/count mismatch ({m},{k},{n})"));
            }
            if tw.overflow != tw_ref.overflow {
                return Err("overflow flag mismatch".into());
            }
            Ok(())
        });
    }
}
