//! Classic ELLPACK format — the paper's section 3.1 baseline.
//!
//! Rows are padded to the *global* maximum nnz (that is exactly the
//! weakness the paper attacks: one heavy row inflates every row's
//! storage, and packing requires a full pass over the dense matrix, so
//! it cannot be fused into a tiled matmul epilogue).

use crate::sparse::{dense, par};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct EllMatrix {
    pub m: usize,
    pub n: usize,
    /// padded width = max row nnz
    pub width: usize,
    pub values: Vec<f32>,  // (m, width)
    pub indices: Vec<u32>, // (m, width)
    /// ELLPACK-R per-row counts (Vazquez et al. 2010)
    pub row_nnz: Vec<u32>,
}

impl EllMatrix {
    /// Pack a dense matrix.  NOTE: requires the full dense matrix up
    /// front — this is the extra pass TwELL eliminates.
    pub fn from_dense(h: &Mat) -> EllMatrix {
        let (m, n) = (h.rows, h.cols);
        let mut counts = vec![0u32; m];
        for r in 0..m {
            counts[r] = h.row(r).iter().filter(|&&v| v != 0.0).count() as u32;
        }
        let width = counts.iter().copied().max().unwrap_or(0) as usize;
        let mut values = vec![0f32; m * width];
        let mut indices = vec![0u32; m * width];
        for r in 0..m {
            let mut z = 0usize;
            for (c, &v) in h.row(r).iter().enumerate() {
                if v != 0.0 {
                    values[r * width + z] = v;
                    indices[r * width + z] = c as u32;
                    z += 1;
                }
            }
        }
        EllMatrix { m, n, width, values, indices, row_nnz: counts }
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.m, self.n);
        for r in 0..self.m {
            for z in 0..self.row_nnz[r] as usize {
                let j = r * self.width + z;
                out.data[r * self.n + self.indices[j] as usize] =
                    self.values[j];
            }
        }
        out
    }

    pub fn bytes(&self) -> u64 {
        (self.values.len() * 4 + self.indices.len() * 4 + self.m * 4) as u64
    }

    /// y = self @ W — the classic ELL SpMM (section 3.1): one parallel
    /// accumulation per row, gathering W rows by stored indices.
    pub fn matmul(&self, w: &Mat) -> Mat {
        assert_eq!(w.rows, self.n);
        let k = w.cols;
        let mut y = Mat::zeros(self.m, k);
        par::for_row_blocks_out(self.m, k, &mut y.data, |lo, hi, out| {
            for r in lo..hi {
                let yrow = &mut out[(r - lo) * k..(r - lo + 1) * k];
                for z in 0..self.row_nnz[r] as usize {
                    let j = r * self.width + z;
                    dense::axpy(
                        self.values[j],
                        w.row(self.indices[j] as usize),
                        yrow,
                    );
                }
            }
        });
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    fn sparse_mat(m: usize, n: usize, density: f32, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let mut h = Mat::zeros(m, n);
        for v in h.data.iter_mut() {
            if rng.f32() < density {
                *v = rng.f32() + 0.01;
            }
        }
        h
    }

    #[test]
    fn roundtrip() {
        let h = sparse_mat(16, 40, 0.2, 1);
        let e = EllMatrix::from_dense(&h);
        assert_eq!(e.to_dense(), h);
    }

    #[test]
    fn matmul_matches_dense() {
        let h = sparse_mat(16, 40, 0.2, 2);
        let mut rng = Pcg32::seeded(3);
        let w = Mat::randn(40, 12, 0.5, &mut rng);
        let e = EllMatrix::from_dense(&h);
        let y = e.matmul(&w);
        let yd = dense::matmul(&h, &w);
        assert!(y.rel_err(&yd) < 1e-4);
    }

    #[test]
    fn width_is_global_max() {
        // one heavy row pads everything — the ELL pathology the paper
        // fixes with the hybrid format
        let mut h = sparse_mat(16, 64, 0.05, 4);
        for c in 0..60 {
            h.data[5 * 64 + c] = 1.0;
        }
        let e = EllMatrix::from_dense(&h);
        assert!(e.width >= 60);
        assert!(e.bytes() > 16 * 60 * 4);
    }

    #[test]
    fn prop_ell_matmul_matches_dense() {
        check("ell matmul == dense", 20, 13, |g: &mut Gen| {
            let m = g.dim(30);
            let n = g.dim(64);
            let k = g.dim(20);
            let density = g.f32_in(0.0, 1.0);
            let h = sparse_mat(m, n, density, g.rng.next_u64());
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let w = Mat::randn(n, k, 0.5, &mut rng);
            let e = EllMatrix::from_dense(&h);
            if e.to_dense() != h {
                return Err("roundtrip failed".into());
            }
            let err = e.matmul(&w).rel_err(&dense::matmul(&h, &w));
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("rel err {err}"))
            }
        });
    }
}
