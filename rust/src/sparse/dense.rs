//! Dense matmul baseline — the cuBLAS / WGMMA stand-in.
//!
//! Cache-blocked `i-k-j` kernel with 4x-unrolled AXPY inner loops over
//! row-major operands.  Large-M shapes parallelize over output-row
//! blocks; skinny shapes (decode at batch ≤ 16, where a row split
//! would idle every core but one) dispatch **column-parallel**: all
//! threads walk the same few rows, each owning a disjoint column range
//! of the output.  Both dispatches compute every output element with
//! the identical sequential accumulation order, so results are
//! bit-exact across thread counts and dispatch shapes.  This is the
//! baseline every sparse speedup in the benches is measured against,
//! so it must itself be a respectable CPU matmul (§Perf tracks its
//! GFLOP/s against the machine's practical roofline).
//!
//! The `_into` variants write into caller-owned storage — the decode
//! scratch reuses one set of buffers across every engine iteration.

use crate::sparse::par;
use crate::tensor::Mat;

/// Panel width over k for L1-friendly blocking.
const KB: usize = 64;

/// C = A @ B for row-major A (m,k), B (k,n).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_into(a, b, &mut c);
    c
}

/// C = A @ B into a pre-shaped `c` (fully overwritten).  Skinny M
/// dispatches column-parallel; everything else row-parallel.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.fill(0.0);
    if par::use_col_dispatch(m, n, m * k) {
        let base = par::SendPtr::new(c.data.as_mut_ptr());
        par::for_col_blocks(n, m * k, |lo, hi| {
            matmul_col_block(&a.data, &b.data, &base, m, k, n, lo, hi);
        });
    } else {
        par::for_row_blocks_out(m, n, &mut c.data, |lo, hi, out| {
            matmul_block(&a.data, &b.data, out, lo, hi, k, n);
        });
    }
}

/// The column-range worker: same kb-panel / row / k-step order as
/// `matmul_block`, restricted to output columns `[lo, hi)` — per
/// element the accumulation sequence is identical, which keeps the two
/// dispatches bit-exact.
fn matmul_col_block(
    a: &[f32], b: &[f32], out: &par::SendPtr<f32>, m: usize, k: usize,
    n: usize, lo: usize, hi: usize,
) {
    let w = hi - lo;
    for kb in (0..k).step_by(KB) {
        let ke = (kb + KB).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            // SAFETY: column ranges are disjoint across pool workers
            let crow = unsafe {
                std::slice::from_raw_parts_mut(out.get().add(i * n + lo), w)
            };
            for kk in kb..ke {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, &b[kk * n + lo..kk * n + hi], crow);
            }
        }
    }
}

fn matmul_block(
    a: &[f32], b: &[f32], out: &mut [f32], lo: usize, hi: usize, k: usize,
    n: usize,
) {
    for kb in (0..k).step_by(KB) {
        let ke = (kb + KB).min(k);
        for i in lo..hi {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for kk in kb..ke {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, &b[kk * n..(kk + 1) * n], crow);
            }
        }
    }
}

/// y += alpha * x, 4x unrolled (the compiler vectorizes this well).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = y.len();
    let n4 = n & !3;
    let (x4, xr) = x.split_at(n4);
    let (y4, yr) = y.split_at_mut(n4);
    for (yc, xc) in y4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (yv, xv) in yr.iter_mut().zip(xr) {
        *yv += alpha * xv;
    }
}

/// dot(x, y), 4 partial accumulators for ILP.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n4 = x.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (xa, xr) = x.split_at(n4);
    let (ya, yr) = y.split_at(n4);
    for (xc, yc) in xa.chunks_exact(4).zip(ya.chunks_exact(4)) {
        s0 += xc[0] * yc[0];
        s1 += xc[1] * yc[1];
        s2 += xc[2] * yc[2];
        s3 += xc[3] * yc[3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for (xv, yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

/// C = ReLU(A @ B) — the dense gate projection (what algorithm 1 fuses
/// the pack into).
pub fn matmul_relu(a: &Mat, b: &Mat) -> Mat {
    let mut c = matmul(a, b);
    for v in c.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    c
}

/// `matmul_relu` into a pre-shaped output.
pub fn matmul_relu_into(a: &Mat, b: &Mat, c: &mut Mat) {
    matmul_into(a, b, c);
    for v in c.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// C = A^T @ B for A (m,k), B (m,n) -> (k,n).  Used by the dense
/// training-step baseline for weight gradients (x^T dh etc.).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(k, n);
    par::for_row_blocks_out(k, n, &mut c.data, |lo, hi, out| {
        for mm in 0..m {
            let arow = &a.data[mm * k..(mm + 1) * k];
            let brow = &b.data[mm * n..(mm + 1) * n];
            for kk in lo..hi {
                let av = arow[kk];
                if av != 0.0 {
                    axpy(av, brow, &mut out[(kk - lo) * n..(kk - lo + 1) * n]);
                }
            }
        }
    });
    c
}

/// C = A @ B^T for A (m,k), B (n,k) -> (m,n): contiguous row-dot kernel.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.rows);
    matmul_nt_into(a, b, &mut c);
    c
}

/// `matmul_nt` into a pre-shaped output (fully overwritten).  The
/// logits projection `(B, d) @ (V, d)^T` at decode batch sizes lands
/// on the column-parallel path: each worker owns a disjoint slice of
/// the vocabulary, and every element is one independent dot, so the
/// dispatch shape cannot change a bit of the result.  The routed
/// decode FFN (`sparse::route`) leans on the same property for its
/// union up-projection: each gathered-slice element is one `dot`,
/// bit-identical to the fused kernel's implicit h_u element.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows));
    let (m, k, n) = (a.rows, a.cols, b.rows);
    if par::use_col_dispatch(m, n, m * k) {
        let base = par::SendPtr::new(c.data.as_mut_ptr());
        par::for_col_blocks(n, m * k, |lo, hi| {
            for i in 0..m {
                let arow = a.row(i);
                // SAFETY: column ranges are disjoint across workers
                let crow = unsafe {
                    std::slice::from_raw_parts_mut(
                        base.get().add(i * n + lo),
                        hi - lo,
                    )
                };
                for (j, cv) in (lo..hi).zip(crow.iter_mut()) {
                    *cv = dot(arow, b.row(j));
                }
            }
        });
    } else {
        par::for_row_blocks_out(m, n, &mut c.data, |lo, hi, out| {
            for i in lo..hi {
                let arow = a.row(i);
                let crow = &mut out[(i - lo) * n..(i - lo + 1) * n];
                for j in 0..n {
                    crow[j] = dot(arow, b.row(j));
                }
            }
        });
    }
}

/// Naive triple loop for testing only.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let aik = a.at(i, kk);
            for j in 0..b.cols {
                *c.at_mut(i, j) += aik * b.at(kk, j);
            }
        }
    }
    c
}

/// The dense gated FFN forward (eq. 1) — the inference baseline.
pub fn gated_ffn(x: &Mat, wg: &Mat, wu: &Mat, wd: &Mat) -> Mat {
    let hg = matmul_relu(x, wg);
    let hu = matmul(x, wu);
    let mut h = hg;
    for (hv, uv) in h.data.iter_mut().zip(&hu.data) {
        *hv *= uv;
    }
    matmul(&h, wd)
}

/// Non-gated FFN forward (eq. 5) baseline.
pub fn nongated_ffn(x: &Mat, wu: &Mat, wd: &Mat) -> Mat {
    let h = matmul_relu(x, wu);
    matmul(&h, wd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    #[test]
    fn matches_naive() {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::randn(13, 31, 1.0, &mut rng);
        let b = Mat::randn(31, 17, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let cn = matmul_naive(&a, &b);
        assert!(c.rel_err(&cn) < 1e-5, "{}", c.rel_err(&cn));
    }

    #[test]
    fn relu_clamps() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let c = matmul_relu(&a, &b);
        assert!(c.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn dot_and_axpy_agree_with_scalar() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let y: Vec<f32> = (0..37).map(|i| (37 - i) as f32 * 0.2).collect();
        let expect: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - expect).abs() < 1e-3);
        let mut z = y.clone();
        axpy(2.0, &x, &mut z);
        for i in 0..37 {
            assert!((z[i] - (y[i] + 2.0 * x[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_matmul_matches_naive() {
        check("dense matmul == naive", 30, 42, |g: &mut Gen| {
            let m = g.dim(40);
            let k = g.dim(64);
            let n = g.dim(48);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let cn = matmul_naive(&a, &b);
            let err = c.rel_err(&cn);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("rel err {err} at ({m},{k},{n})"))
            }
        });
    }

    #[test]
    fn skinny_col_dispatch_matches_naive() {
        // shapes chosen to clear the column-parallel work cutoff
        // (m < 32, n * m * k >= PAR_MIN_COL_WORK)
        let mut rng = Pcg32::seeded(17);
        let a = Mat::randn(4, 96, 1.0, &mut rng);
        let b = Mat::randn(96, 512, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let cn = matmul_naive(&a, &b);
        assert!(c.rel_err(&cn) < 1e-4, "{}", c.rel_err(&cn));
        let bt = Mat::randn(512, 96, 1.0, &mut rng);
        let nt = matmul_nt(&a, &bt);
        let expect = matmul_naive(&a, &bt.transpose());
        assert!(nt.rel_err(&expect) < 1e-4);
    }

    /// The determinism contract on the decode-shaped GEMMs: bit-exact
    /// output for any thread count and for the seed row dispatch vs
    /// the pooled column-parallel fast path.
    #[test]
    fn skinny_matmuls_bit_exact_across_threads_and_dispatch() {
        let _g = par::test_guard();
        let orig = par::num_threads();
        let mut rng = Pcg32::seeded(23);
        let a = Mat::randn(4, 96, 1.0, &mut rng);
        let b = Mat::randn(96, 512, 1.0, &mut rng);
        let bt = Mat::randn(512, 96, 1.0, &mut rng);
        let mut runs = Vec::new();
        for &threads in &[1usize, 4] {
            for &fast in &[false, true] {
                par::set_threads(threads);
                par::set_skinny_fast_path(fast);
                runs.push((
                    format!("t={threads} fast={fast}"),
                    matmul(&a, &b).data,
                    matmul_nt(&a, &bt).data,
                ));
            }
        }
        par::set_threads(orig);
        par::set_skinny_fast_path(true);
        for (label, mm, nt) in &runs[1..] {
            assert_eq!(mm, &runs[0].1, "matmul diverged at {label}");
            assert_eq!(nt, &runs[0].2, "matmul_nt diverged at {label}");
        }
    }

    #[test]
    fn into_variants_fully_overwrite_stale_scratch() {
        let mut rng = Pcg32::seeded(31);
        let a = Mat::randn(6, 16, 1.0, &mut rng);
        let b = Mat::randn(16, 24, 1.0, &mut rng);
        let mut c = Mat::zeros(6, 24);
        c.data.fill(f32::NAN); // poison: any unwritten element survives
        matmul_into(&a, &b, &mut c);
        assert_eq!(c.data, matmul(&a, &b).data);
        let bt = Mat::randn(24, 16, 1.0, &mut rng);
        let mut d = Mat::zeros(6, 24);
        d.data.fill(f32::NAN);
        matmul_nt_into(&a, &bt, &mut d);
        assert_eq!(d.data, matmul_nt(&a, &bt).data);
    }

    #[test]
    fn matmul_tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg32::seeded(9);
        let a = Mat::randn(11, 7, 1.0, &mut rng);
        let b = Mat::randn(11, 5, 1.0, &mut rng);
        let tn = matmul_tn(&a, &b);
        let expect = matmul_naive(&a.transpose(), &b);
        assert!(tn.rel_err(&expect) < 1e-5);
        let c = Mat::randn(9, 7, 1.0, &mut rng);
        let nt = matmul_nt(&a, &c);
        let expect2 = matmul_naive(&a, &c.transpose());
        assert!(nt.rel_err(&expect2) < 1e-5);
    }

    #[test]
    fn gated_ffn_formula() {
        let mut rng = Pcg32::seeded(3);
        let x = Mat::randn(6, 8, 1.0, &mut rng);
        let wg = Mat::randn(8, 12, 0.3, &mut rng);
        let wu = Mat::randn(8, 12, 0.3, &mut rng);
        let wd = Mat::randn(12, 8, 0.3, &mut rng);
        let y = gated_ffn(&x, &wg, &wu, &wd);
        // scalar recomputation
        for i in 0..6 {
            for j in 0..8 {
                let mut acc = 0f32;
                for h in 0..12 {
                    let g: f32 = (0..8).map(|k| x.at(i, k) * wg.at(k, h)).sum();
                    let u: f32 = (0..8).map(|k| x.at(i, k) * wu.at(k, h)).sum();
                    acc += g.max(0.0) * u * wd.at(h, j);
                }
                assert!((acc - y.at(i, j)).abs() < 1e-3);
            }
        }
    }
}
