//! Dense matmul baseline — the cuBLAS / WGMMA stand-in.
//!
//! Cache-blocked `i-k-j` kernel with 4x-unrolled AXPY inner loops over
//! row-major operands, parallelized over output-row blocks.  This is the
//! baseline every sparse speedup in the benches is measured against, so
//! it must itself be a respectable CPU matmul (§Perf tracks its GFLOP/s
//! against the machine's practical roofline).

use crate::sparse::par;
use crate::tensor::Mat;

/// Panel width over k for L1-friendly blocking.
const KB: usize = 64;

/// C = A @ B for row-major A (m,k), B (k,n).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    par::for_row_blocks_out(m, n, &mut c.data, |lo, hi, out| {
        matmul_block(&a.data, &b.data, out, lo, hi, k, n);
    });
    c
}

fn matmul_block(
    a: &[f32], b: &[f32], out: &mut [f32], lo: usize, hi: usize, k: usize,
    n: usize,
) {
    for kb in (0..k).step_by(KB) {
        let ke = (kb + KB).min(k);
        for i in lo..hi {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for kk in kb..ke {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                axpy(aik, &b[kk * n..(kk + 1) * n], crow);
            }
        }
    }
}

/// y += alpha * x, 4x unrolled (the compiler vectorizes this well).
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = y.len();
    let n4 = n & !3;
    let (x4, xr) = x.split_at(n4);
    let (y4, yr) = y.split_at_mut(n4);
    for (yc, xc) in y4.chunks_exact_mut(4).zip(x4.chunks_exact(4)) {
        yc[0] += alpha * xc[0];
        yc[1] += alpha * xc[1];
        yc[2] += alpha * xc[2];
        yc[3] += alpha * xc[3];
    }
    for (yv, xv) in yr.iter_mut().zip(xr) {
        *yv += alpha * xv;
    }
}

/// dot(x, y), 4 partial accumulators for ILP.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n4 = x.len() & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (xa, xr) = x.split_at(n4);
    let (ya, yr) = y.split_at(n4);
    for (xc, yc) in xa.chunks_exact(4).zip(ya.chunks_exact(4)) {
        s0 += xc[0] * yc[0];
        s1 += xc[1] * yc[1];
        s2 += xc[2] * yc[2];
        s3 += xc[3] * yc[3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for (xv, yv) in xr.iter().zip(yr) {
        s += xv * yv;
    }
    s
}

/// C = ReLU(A @ B) — the dense gate projection (what algorithm 1 fuses
/// the pack into).
pub fn matmul_relu(a: &Mat, b: &Mat) -> Mat {
    let mut c = matmul(a, b);
    for v in c.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    c
}

/// C = A^T @ B for A (m,k), B (m,n) -> (k,n).  Used by the dense
/// training-step baseline for weight gradients (x^T dh etc.).
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(k, n);
    par::for_row_blocks_out(k, n, &mut c.data, |lo, hi, out| {
        for mm in 0..m {
            let arow = &a.data[mm * k..(mm + 1) * k];
            let brow = &b.data[mm * n..(mm + 1) * n];
            for kk in lo..hi {
                let av = arow[kk];
                if av != 0.0 {
                    axpy(av, brow, &mut out[(kk - lo) * n..(kk - lo + 1) * n]);
                }
            }
        }
    });
    c
}

/// C = A @ B^T for A (m,k), B (n,k) -> (m,n): contiguous row-dot kernel.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols);
    let (m, n) = (a.rows, b.rows);
    let mut c = Mat::zeros(m, n);
    par::for_row_blocks_out(m, n, &mut c.data, |lo, hi, out| {
        for i in lo..hi {
            let arow = a.row(i);
            let crow = &mut out[(i - lo) * n..(i - lo + 1) * n];
            for j in 0..n {
                crow[j] = dot(arow, b.row(j));
            }
        }
    });
    c
}

/// Naive triple loop for testing only.
pub fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for kk in 0..a.cols {
            let aik = a.at(i, kk);
            for j in 0..b.cols {
                *c.at_mut(i, j) += aik * b.at(kk, j);
            }
        }
    }
    c
}

/// The dense gated FFN forward (eq. 1) — the inference baseline.
pub fn gated_ffn(x: &Mat, wg: &Mat, wu: &Mat, wd: &Mat) -> Mat {
    let hg = matmul_relu(x, wg);
    let hu = matmul(x, wu);
    let mut h = hg;
    for (hv, uv) in h.data.iter_mut().zip(&hu.data) {
        *hv *= uv;
    }
    matmul(&h, wd)
}

/// Non-gated FFN forward (eq. 5) baseline.
pub fn nongated_ffn(x: &Mat, wu: &Mat, wd: &Mat) -> Mat {
    let h = matmul_relu(x, wu);
    matmul(&h, wd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    #[test]
    fn matches_naive() {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::randn(13, 31, 1.0, &mut rng);
        let b = Mat::randn(31, 17, 1.0, &mut rng);
        let c = matmul(&a, &b);
        let cn = matmul_naive(&a, &b);
        assert!(c.rel_err(&cn) < 1e-5, "{}", c.rel_err(&cn));
    }

    #[test]
    fn relu_clamps() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let c = matmul_relu(&a, &b);
        assert!(c.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn dot_and_axpy_agree_with_scalar() {
        let x: Vec<f32> = (0..37).map(|i| i as f32 * 0.1).collect();
        let y: Vec<f32> = (0..37).map(|i| (37 - i) as f32 * 0.2).collect();
        let expect: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - expect).abs() < 1e-3);
        let mut z = y.clone();
        axpy(2.0, &x, &mut z);
        for i in 0..37 {
            assert!((z[i] - (y[i] + 2.0 * x[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_matmul_matches_naive() {
        check("dense matmul == naive", 30, 42, |g: &mut Gen| {
            let m = g.dim(40);
            let k = g.dim(64);
            let n = g.dim(48);
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let b = Mat::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let cn = matmul_naive(&a, &b);
            let err = c.rel_err(&cn);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("rel err {err} at ({m},{k},{n})"))
            }
        });
    }

    #[test]
    fn matmul_tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg32::seeded(9);
        let a = Mat::randn(11, 7, 1.0, &mut rng);
        let b = Mat::randn(11, 5, 1.0, &mut rng);
        let tn = matmul_tn(&a, &b);
        let expect = matmul_naive(&a.transpose(), &b);
        assert!(tn.rel_err(&expect) < 1e-5);
        let c = Mat::randn(9, 7, 1.0, &mut rng);
        let nt = matmul_nt(&a, &c);
        let expect2 = matmul_naive(&a, &c.transpose());
        assert!(nt.rel_err(&expect2) < 1e-5);
    }

    #[test]
    fn gated_ffn_formula() {
        let mut rng = Pcg32::seeded(3);
        let x = Mat::randn(6, 8, 1.0, &mut rng);
        let wg = Mat::randn(8, 12, 0.3, &mut rng);
        let wu = Mat::randn(8, 12, 0.3, &mut rng);
        let wd = Mat::randn(12, 8, 0.3, &mut rng);
        let y = gated_ffn(&x, &wg, &wu, &wd);
        // scalar recomputation
        for i in 0..6 {
            for j in 0..8 {
                let mut acc = 0f32;
                for h in 0..12 {
                    let g: f32 = (0..8).map(|k| x.at(i, k) * wg.at(k, h)).sum();
                    let u: f32 = (0..8).map(|k| x.at(i, k) * wu.at(k, h)).sum();
                    acc += g.max(0.0) * u * wd.at(h, j);
                }
                assert!((acc - y.at(i, j)).abs() < 1e-3);
            }
        }
    }
}
