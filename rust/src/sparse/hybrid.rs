//! Hybrid ELL + dense training format (paper sections 3.4-3.5,
//! algorithm 3, listings 4-7).
//!
//! Rows whose non-zero count fits in an aggressively compact fixed width
//! `ell_width` live in an ELL component; heavier rows are routed to a
//! statically pre-allocated dense backup tail (appendix B.2.1 sizing:
//! width 128, tail = M/8 rows at the paper's scale).  Overflow beyond the
//! tail capacity sets a flag that the coordinator reacts to by enlarging
//! the structures and retrying the step — never a hard failure.

use crate::sparse::twell::TwellMatrix;
use crate::sparse::{dense, par};
use crate::tensor::Mat;

#[derive(Clone, Debug)]
pub struct HybridMatrix {
    pub m: usize,
    pub n: usize,
    pub ell_width: usize,
    /// ELL values, (m, ell_width); rows routed dense leave theirs zeroed
    pub ell_val: Vec<f32>,
    /// ELL column indices, (m, ell_width)
    pub ell_col: Vec<u16>,
    /// true per-row non-zero count (may exceed ell_width)
    pub row_nnz: Vec<u32>,
    /// row routed to the dense tail?
    pub is_dense: Vec<bool>,
    /// dense backup rows, (capacity, n)
    pub dense_tail: Vec<f32>,
    /// row -> tail slot (or -1)
    pub dense_map: Vec<i32>,
    pub tail_capacity: usize,
    pub tail_rows: usize,
    /// set when a dense row could not be stored (flag-and-retry contract)
    pub overflow: bool,
}

impl HybridMatrix {
    fn empty(m: usize, n: usize, ell_width: usize, cap: usize) -> Self {
        HybridMatrix {
            m,
            n,
            ell_width,
            ell_val: vec![0.0; m * ell_width],
            ell_col: vec![0; m * ell_width],
            row_nnz: vec![0; m],
            is_dense: vec![false; m],
            dense_tail: vec![0.0; cap * n],
            dense_map: vec![-1; m],
            tail_capacity: cap,
            tail_rows: 0,
            overflow: false,
        }
    }

    /// Listing 4: convert TwELL storage into the hybrid format with a
    /// per-row prefix scan over tile counts; also accumulates the L0/L1
    /// statistics the training loss needs.
    pub fn from_twell(
        tw: &TwellMatrix, ell_width: usize, max_dense_rows: usize,
    ) -> (Self, f64, f64) {
        let mut h = HybridMatrix::empty(tw.m, tw.n, ell_width, max_dense_rows);
        let n_tiles = tw.n_tiles();
        let slots = tw.slots();
        let pc = tw.packed_cols();
        let mut l0 = 0f64;
        let mut l1 = 0f64;
        for r in 0..tw.m {
            // prefix scan of tile counts = destination offsets
            let total: u32 = (0..n_tiles)
                .map(|t| tw.nnz[r * n_tiles + t] as u32)
                .sum();
            h.row_nnz[r] = total;
            l0 += total as f64;
            if total as usize <= ell_width {
                let mut dst = 0usize;
                for t in 0..n_tiles {
                    let z = tw.nnz[r * n_tiles + t] as usize;
                    let base = r * pc + t * slots;
                    for c in 0..z {
                        h.ell_val[r * ell_width + dst] = tw.values[base + c];
                        h.ell_col[r * ell_width + dst] = tw.indices[base + c];
                        l1 += tw.values[base + c].abs() as f64;
                        dst += 1;
                    }
                }
            } else {
                h.is_dense[r] = true;
                if h.tail_rows < max_dense_rows {
                    let slot = h.tail_rows;
                    h.dense_map[r] = slot as i32;
                    h.tail_rows += 1;
                    let tail =
                        &mut h.dense_tail[slot * tw.n..(slot + 1) * tw.n];
                    for t in 0..n_tiles {
                        let z = tw.nnz[r * n_tiles + t] as usize;
                        let base = r * pc + t * slots;
                        for c in 0..z {
                            tail[tw.indices[base + c] as usize] =
                                tw.values[base + c];
                            l1 += tw.values[base + c].abs() as f64;
                        }
                    }
                } else {
                    h.overflow = true; // drop + flag (appendix B.2.1)
                }
            }
        }
        (h, l0, l1)
    }

    /// Test/bench helper: partition a dense matrix directly.
    pub fn from_dense(
        src: &Mat, ell_width: usize, max_dense_rows: usize,
    ) -> Self {
        let mut h = HybridMatrix::empty(src.rows, src.cols, ell_width,
                                        max_dense_rows);
        for r in 0..src.rows {
            let row = src.row(r);
            let nnz = row.iter().filter(|&&v| v != 0.0).count();
            h.row_nnz[r] = nnz as u32;
            if nnz <= ell_width {
                let mut dst = 0;
                for (c, &v) in row.iter().enumerate() {
                    if v != 0.0 {
                        h.ell_val[r * ell_width + dst] = v;
                        h.ell_col[r * ell_width + dst] = c as u16;
                        dst += 1;
                    }
                }
            } else {
                h.is_dense[r] = true;
                if h.tail_rows < max_dense_rows {
                    let slot = h.tail_rows;
                    h.dense_map[r] = slot as i32;
                    h.tail_rows += 1;
                    h.dense_tail[slot * src.cols..(slot + 1) * src.cols]
                        .copy_from_slice(row);
                } else {
                    h.overflow = true;
                }
            }
        }
        h
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.m, self.n);
        for r in 0..self.m {
            if self.is_dense[r] {
                let d = self.dense_map[r];
                if d >= 0 {
                    out.row_mut(r).copy_from_slice(
                        &self.dense_tail
                            [d as usize * self.n..(d as usize + 1) * self.n],
                    );
                }
            } else {
                for z in 0..self.row_nnz[r] as usize {
                    let j = r * self.ell_width + z;
                    out.data[r * self.n + self.ell_col[j] as usize] =
                        self.ell_val[j];
                }
            }
        }
        out
    }

    /// Storage footprint (figure 1c / table 1 memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.ell_val.len() * 4
            + self.ell_col.len() * 2
            + self.m * 5
            + self.tail_capacity * self.n * 4) as u64
    }

    /// Algorithm 3 / listing 6: C = hybrid(A) @ W, W is (n, k) dense.
    pub fn matmul(&self, w: &Mat) -> Mat {
        assert_eq!(w.rows, self.n);
        let k = w.cols;
        let mut y = Mat::zeros(self.m, k);
        par::for_row_blocks_out(self.m, k, &mut y.data, |lo, hi, out| {
            for r in lo..hi {
                let yrow = &mut out[(r - lo) * k..(r - lo + 1) * k];
                if self.is_dense[r] {
                    // dense-tail row: "tensor core" path (tiled dense dot)
                    let d = self.dense_map[r];
                    if d >= 0 {
                        let arow = &self.dense_tail
                            [d as usize * self.n..(d as usize + 1) * self.n];
                        for (c, &av) in arow.iter().enumerate() {
                            if av != 0.0 {
                                dense::axpy(av, w.row(c), yrow);
                            }
                        }
                    }
                } else {
                    // ELL row: CUDA-core path (gather-axpy per non-zero)
                    for z in 0..self.row_nnz[r] as usize {
                        let j = r * self.ell_width + z;
                        dense::axpy(
                            self.ell_val[j],
                            w.row(self.ell_col[j] as usize),
                            yrow,
                        );
                    }
                }
            }
        });
        y
    }

    /// Listing 5: dense-to-hybrid matmul — compute `A @ B` only at the
    /// sparsity pattern of `self`, returning a hybrid with the same
    /// routing.  `b_t` is B transposed, (n, k) row-major, so each needed
    /// output column is a contiguous dot.  Used for the up projection in
    /// the forward pass and the masked gradient matmuls in the backward.
    pub fn dense_to_hybrid_matmul(&self, a: &Mat, b_t: &Mat) -> HybridMatrix {
        assert_eq!(a.rows, self.m);
        assert_eq!(b_t.cols, a.cols);
        assert_eq!(b_t.rows, self.n);
        let k = a.cols;
        let mut out = HybridMatrix {
            ell_val: vec![0.0; self.m * self.ell_width],
            dense_tail: vec![0.0; self.tail_capacity * self.n],
            ..self.shallow_clone_structure()
        };
        let val_ptr = par::SendPtr::new(out.ell_val.as_mut_ptr());
        let tail_ptr = par::SendPtr::new(out.dense_tail.as_mut_ptr());
        par::for_row_blocks(self.m, |lo, hi| {
            for r in lo..hi {
                let arow = a.row(r);
                if self.is_dense[r] {
                    let d = self.dense_map[r];
                    if d < 0 {
                        continue;
                    }
                    let src = &self.dense_tail
                        [d as usize * self.n..(d as usize + 1) * self.n];
                    // SAFETY: tail slot `d` belongs to row `r` alone
                    // (`dense_map` is injective), rows are disjoint
                    // across row blocks, and `out.dense_tail` outlives
                    // the pool barrier inside `for_row_blocks`.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            tail_ptr.get().add(d as usize * self.n),
                            self.n,
                        )
                    };
                    // dense row masked by the pattern (listing 5's tensor
                    // core branch with a binary mask)
                    for (c, (&pv, dv)) in
                        src.iter().zip(dst.iter_mut()).enumerate()
                    {
                        if pv != 0.0 {
                            *dv = dense::dot(arow, b_t.row(c));
                        }
                    }
                } else {
                    let z = (self.row_nnz[r] as usize).min(self.ell_width);
                    // SAFETY: the ELL stripe for row `r` is written only
                    // by the block that owns `r` (row ranges are
                    // disjoint), and `out.ell_val` outlives the pool
                    // barrier inside `for_row_blocks`.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(
                            val_ptr.get().add(r * self.ell_width),
                            self.ell_width,
                        )
                    };
                    for zz in 0..z {
                        let col =
                            self.ell_col[r * self.ell_width + zz] as usize;
                        dst[zz] = dense::dot(arow, b_t.row(col));
                    }
                }
            }
        });
        let _ = k;
        out
    }

    /// Same-pattern elementwise product (used for ∇h_u = ∇h ⊙ h_g etc.,
    /// eq. 4).  `self` provides the structure; values are a ⊙ b.
    pub fn mul_same_pattern(&self, other: &HybridMatrix) -> HybridMatrix {
        assert_eq!(self.m, other.m);
        assert_eq!(self.n, other.n);
        let mut out = self.clone();
        for (o, b) in out.ell_val.iter_mut().zip(&other.ell_val) {
            *o *= b;
        }
        for (o, b) in out.dense_tail.iter_mut().zip(&other.dense_tail) {
            *o *= b;
        }
        out
    }

    /// L1-gradient injection (section 3.5): add `coeff * sign(h)` at every
    /// stored position of the pattern, where `h` supplies the signs.
    pub fn inject_l1_grad(&mut self, h: &HybridMatrix, coeff: f32) {
        for (g, &v) in self.ell_val.iter_mut().zip(&h.ell_val) {
            if v != 0.0 {
                *g += coeff * v.signum();
            }
        }
        for (g, &v) in self.dense_tail.iter_mut().zip(&h.dense_tail) {
            if v != 0.0 {
                *g += coeff * v.signum();
            }
        }
    }

    /// Listing 7: transpose within the hybrid format.  Two-pass CPU
    /// rendering of the atomic-slot-reservation kernel: count per output
    /// row, then route rows whose transposed count exceeds the width to
    /// the new dense tail.
    pub fn transpose(
        &self, ell_width: usize, max_dense_rows: usize,
    ) -> HybridMatrix {
        let mut counts = vec![0u32; self.n];
        let mut visit = |col: usize| counts[col] += 1;
        self.for_each_nonzero(|_r, c, _v| visit(c));
        let mut out = HybridMatrix::empty(self.n, self.m, ell_width,
                                          max_dense_rows);
        for (c, &cnt) in counts.iter().enumerate() {
            out.row_nnz[c] = cnt;
            if cnt as usize > ell_width {
                out.is_dense[c] = true;
                if out.tail_rows < max_dense_rows {
                    out.dense_map[c] = out.tail_rows as i32;
                    out.tail_rows += 1;
                } else {
                    out.overflow = true;
                }
            }
        }
        let mut fill = vec![0u32; self.n];
        self.for_each_nonzero(|r, c, v| {
            if out.is_dense[c] {
                let d = out.dense_map[c];
                if d >= 0 {
                    out.dense_tail[d as usize * self.m + r] = v;
                }
            } else {
                let z = fill[c] as usize;
                out.ell_val[c * ell_width + z] = v;
                out.ell_col[c * ell_width + z] = r as u16;
                fill[c] += 1;
            }
        });
        out
    }

    /// Sum of |value| over all stored entries (eq. 2's L1 statistic).
    pub fn l1_sum(&self) -> f64 {
        let mut s = 0f64;
        self.for_each_nonzero(|_r, _c, v| s += v.abs() as f64);
        s
    }

    /// Visit every stored non-zero as (row, col, value).
    pub fn for_each_nonzero<F: FnMut(usize, usize, f32)>(&self, mut f: F) {
        for r in 0..self.m {
            if self.is_dense[r] {
                let d = self.dense_map[r];
                if d >= 0 {
                    let row = &self.dense_tail
                        [d as usize * self.n..(d as usize + 1) * self.n];
                    for (c, &v) in row.iter().enumerate() {
                        if v != 0.0 {
                            f(r, c, v);
                        }
                    }
                }
            } else {
                for z in 0..(self.row_nnz[r] as usize).min(self.ell_width) {
                    let j = r * self.ell_width + z;
                    f(r, self.ell_col[j] as usize, self.ell_val[j]);
                }
            }
        }
    }

    fn shallow_clone_structure(&self) -> HybridMatrix {
        HybridMatrix {
            m: self.m,
            n: self.n,
            ell_width: self.ell_width,
            ell_val: vec![],
            ell_col: self.ell_col.clone(),
            row_nnz: self.row_nnz.clone(),
            is_dense: self.is_dense.clone(),
            dense_tail: vec![],
            dense_map: self.dense_map.clone(),
            tail_capacity: self.tail_capacity,
            tail_rows: self.tail_rows,
            overflow: self.overflow,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::twell::gate_matmul_twell;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    fn sparse_mat(m: usize, n: usize, density: f32, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let mut h = Mat::zeros(m, n);
        for v in h.data.iter_mut() {
            if rng.f32() < density {
                *v = rng.f32() + 0.01;
            }
        }
        h
    }

    #[test]
    fn from_dense_roundtrip_with_tail() {
        let mut h = sparse_mat(16, 64, 0.1, 1);
        for c in 0..50 {
            h.data[4 * 64 + c] = 1.0; // heavy row -> tail
        }
        let hy = HybridMatrix::from_dense(&h, 8, 4);
        assert!(hy.is_dense[4]);
        assert!(!hy.overflow);
        assert_eq!(hy.to_dense(), h);
    }

    #[test]
    fn from_twell_matches_from_dense() {
        let mut rng = Pcg32::seeded(2);
        let mut x = Mat::randn(16, 8, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v -= 0.3;
        }
        let wg = Mat::randn(8, 64, 0.3, &mut rng);
        let tw = gate_matmul_twell(&x, &wg, 32, 1);
        let (hy, l0, l1) = HybridMatrix::from_twell(&tw, 16, 16);
        let hg = dense::matmul_relu(&x, &wg);
        let hy_ref = HybridMatrix::from_dense(&hg, 16, 16);
        assert_eq!(hy.row_nnz, hy_ref.row_nnz);
        assert_eq!(hy.is_dense, hy_ref.is_dense);
        assert!(hy.to_dense().max_abs_diff(&hg) < 1e-4);
        assert_eq!(l0 as u64, hg.nnz_positive() as u64);
        let l1_ref: f64 = hg.data.iter().map(|&v| v.abs() as f64).sum();
        assert!((l1 - l1_ref).abs() / l1_ref.max(1e-9) < 1e-4);
    }

    #[test]
    fn matmul_matches_dense() {
        let mut h = sparse_mat(24, 48, 0.15, 3);
        for c in 0..40 {
            h.data[7 * 48 + c] = 0.5; // tail row
        }
        let mut rng = Pcg32::seeded(4);
        let w = Mat::randn(48, 16, 0.5, &mut rng);
        let hy = HybridMatrix::from_dense(&h, 8, 24);
        assert!(!hy.overflow);
        let y = hy.matmul(&w);
        assert!(y.rel_err(&dense::matmul(&h, &w)) < 1e-4);
    }

    #[test]
    fn dense_to_hybrid_matmul_computes_pattern_only() {
        // pattern = hybrid of hg; compute A @ B at that pattern
        let hg = sparse_mat(16, 32, 0.2, 5);
        let pattern = HybridMatrix::from_dense(&hg, 8, 16);
        let mut rng = Pcg32::seeded(6);
        let a = Mat::randn(16, 12, 0.5, &mut rng);
        let b = Mat::randn(12, 32, 0.5, &mut rng);
        let b_t = b.transpose();
        let out = pattern.dense_to_hybrid_matmul(&a, &b_t);
        let full = dense::matmul(&a, &b);
        let out_dense = out.to_dense();
        for r in 0..16 {
            for c in 0..32 {
                let expect = if hg.at(r, c) != 0.0 { full.at(r, c) } else { 0.0 };
                assert!(
                    (out_dense.at(r, c) - expect).abs() < 1e-4,
                    "({r},{c})"
                );
            }
        }
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let mut h = sparse_mat(20, 40, 0.12, 7);
        for c in 0..35 {
            h.data[3 * 40 + c] = 0.25; // tail row in the source
        }
        let hy = HybridMatrix::from_dense(&h, 8, 4);
        let ht = hy.transpose(8, 40);
        assert_eq!(ht.to_dense(), h.transpose());
    }

    #[test]
    fn transpose_routes_heavy_columns_to_tail() {
        // a column present in every row transposes to a heavy row
        let mut h = sparse_mat(32, 16, 0.05, 8);
        for r in 0..32 {
            h.data[r * 16 + 5] = 1.0;
        }
        let hy = HybridMatrix::from_dense(&h, 8, 8);
        let ht = hy.transpose(8, 8);
        assert!(ht.is_dense[5]);
        assert_eq!(ht.to_dense(), h.transpose());
    }

    #[test]
    fn overflow_flag_on_tail_exhaustion() {
        let mut h = Mat::zeros(8, 32);
        for r in 0..8 {
            for c in 0..20 {
                h.data[r * 32 + c] = 1.0;
            }
        }
        let hy = HybridMatrix::from_dense(&h, 4, 2);
        assert!(hy.overflow);
        assert_eq!(hy.tail_rows, 2);
    }

    #[test]
    fn l1_injection_touches_pattern_only() {
        let h = sparse_mat(8, 16, 0.3, 9);
        let hh = HybridMatrix::from_dense(&h, 8, 2);
        let mut grad = hh.clone();
        for v in grad.ell_val.iter_mut() {
            *v = 0.0;
        }
        for v in grad.dense_tail.iter_mut() {
            *v = 0.0;
        }
        grad.inject_l1_grad(&hh, 0.5);
        let gd = grad.to_dense();
        for r in 0..8 {
            for c in 0..16 {
                let expect = if h.at(r, c) > 0.0 { 0.5 } else { 0.0 };
                assert_eq!(gd.at(r, c), expect);
            }
        }
    }

    #[test]
    fn mul_same_pattern_is_elementwise() {
        let h = sparse_mat(8, 16, 0.4, 10);
        let a = HybridMatrix::from_dense(&h, 16, 2);
        let prod = a.mul_same_pattern(&a);
        let pd = prod.to_dense();
        for r in 0..8 {
            for c in 0..16 {
                assert!((pd.at(r, c) - h.at(r, c) * h.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prop_hybrid_preserves_every_nonzero() {
        check("hybrid partition lossless", 25, 17, |g: &mut Gen| {
            let m = g.dim(32);
            let n = g.dim(64);
            let density = g.f32_in(0.0, 1.0);
            let width = *g.choose(&[4usize, 8, 16]);
            let h = sparse_mat(m, n, density, g.rng.next_u64());
            // tail capacity = m: can never overflow
            let hy = HybridMatrix::from_dense(&h, width, m);
            if hy.overflow {
                return Err("unexpected overflow".into());
            }
            if hy.to_dense() != h {
                return Err(format!("lossy at ({m},{n},{density})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_transpose_involution() {
        check("hybrid transpose involution", 20, 19, |g: &mut Gen| {
            let m = g.dim(24);
            let n = g.dim(24);
            let density = g.f32_in(0.0, 0.8);
            let h = sparse_mat(m, n, density, g.rng.next_u64());
            let hy = HybridMatrix::from_dense(&h, 8, m);
            let back = hy.transpose(8, n).transpose(8, m);
            if back.to_dense() == h {
                Ok(())
            } else {
                Err(format!("involution failed ({m},{n})"))
            }
        });
    }

    #[test]
    fn prop_matmul_matches_dense_across_routing() {
        check("hybrid matmul == dense", 20, 23, |g: &mut Gen| {
            let m = g.dim(24);
            let n = g.dim(48);
            let k = g.dim(16);
            let density = g.f32_in(0.0, 1.0);
            let width = *g.choose(&[2usize, 6, 12]);
            let h = sparse_mat(m, n, density, g.rng.next_u64());
            let mut rng = Pcg32::seeded(g.rng.next_u64());
            let w = Mat::randn(n, k, 0.5, &mut rng);
            let hy = HybridMatrix::from_dense(&h, width, m);
            let err = hy.matmul(&w).rel_err(&dense::matmul(&h, &w));
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("rel err {err}"))
            }
        });
    }
}
