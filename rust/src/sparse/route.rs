//! Batch-contextual sparsity routing for batched decode.
//!
//! The TwELL fused kernel wins on *per-row* sparsity, but a batched
//! decode step unions the batch's activations: at batch 32 a model
//! whose rows are 99% sparse may still touch 30-60% of the FFN columns
//! *somewhere* in the batch, and the row-by-row gather loses to a dense
//! GEMM long before that.  Polar Sparsity's observation is that the
//! routing decision should therefore be **batch-granular**: compute the
//! union of active columns once per feed, and if it is still sparse
//! enough, run the whole batch through a *gathered dense* kernel —
//! Flash-LLM's "load as sparse, compute as dense" idiom.
//!
//! The pipeline per decode step, given the packed gate `h_g` (TwELL):
//!
//! 1. [`build_union`] — walk every row's packed entries (already
//!    ascending by global column) and produce the sorted union
//!    `cols[0..U]`, a column→union-position map, and each row's packed
//!    (position, gate value) list.
//! 2. Gather rows `cols[i]` of `W_u^T` and `W_d` into the persistent
//!    `wu_g` / `wd_g` scratch — bit-copies, parallel over union rows.
//! 3. Up projection as a dense skinny GEMM over the gathered slice:
//!    `ug = x @ wu_g^T` via [`dense::matmul_nt_into`], which computes
//!    every element as one independent [`dense::dot`] — the *same* dot
//!    the fused kernel uses for its implicit h_u elements.
//! 4. Scale each row's gate values by its `ug` entries (the eq. 3
//!    coefficients `v * u`), then accumulate `y += coef * wd_g[p, :]`
//!    column-parallel, walking only each row's **active** union
//!    positions in ascending order.
//!
//! Bit-exactness with the fused TwELL path (`fused::fused_up_down_into`)
//! is by construction: the union is sorted ascending, so each row's
//! active positions enumerate exactly the row's packed columns in the
//! same order the fused kernel walks them; `u` comes from the same
//! `dense::dot`; the coefficient is the same `v * u` product; and the
//! down accumulation *skips* inactive union positions rather than
//! multiplying by zero (`-0.0 + 0.0 == +0.0`, so `y += 0.0 * w` is not
//! a bitwise no-op — a dense masked GEMM would flip sign bits on
//! negative zeros).  The routed path is therefore bitwise invisible:
//! the router can flip between it and the fused path per step without
//! changing a single logit bit.

use crate::sparse::twell::TwellMatrix;
use crate::sparse::{dense, par};
use crate::tensor::Mat;

/// Default union-density threshold for `ServePolicy.route_density`:
/// route while the batch union covers at most this fraction of d_ff.
pub const DEFAULT_ROUTE_DENSITY: f32 = 0.25;

/// Dispatch counters for the decode FFN router (one event per FFN
/// call, i.e. per layer per engine step).  Drained into `EngineStats`
/// by the serving loop via [`RouteStats::take`].
#[derive(Clone, Copy, Debug, Default)]
pub struct RouteStats {
    /// row-parallel dense/fused dispatch (large batch, or fast path off)
    pub row: u64,
    /// column-parallel dense/fused dispatch (skinny batch fast path)
    pub col: u64,
    /// routed union-gather path ran
    pub routed: u64,
    /// routing was considered but fell back (union too dense, or a
    /// ragged prefill span densified the feed)
    pub fallback: u64,
    /// sum of measured union densities (routed + fallback decisions)
    pub density_sum: f64,
    /// number of union-density measurements in `density_sum`
    pub density_calls: u64,
}

impl RouteStats {
    /// Drain: return the current counters and reset to zero.
    pub fn take(&mut self) -> RouteStats {
        std::mem::take(self)
    }

    /// Mean measured union density (0.0 when no decision was measured).
    pub fn mean_density(&self) -> f64 {
        if self.density_calls == 0 {
            0.0
        } else {
            self.density_sum / self.density_calls as f64
        }
    }

    /// The most common dispatch outcome, for bench labels.  Ties break
    /// routed > fallback > col > row.
    pub fn dominant(&self) -> &'static str {
        let mut best = (self.routed, "routed");
        for cat in [
            (self.fallback, "fallback"),
            (self.col, "col"),
            (self.row, "row"),
        ] {
            if cat.0 > best.0 {
                best = cat;
            }
        }
        best.1
    }
}

/// Persistent state for the batch-contextual decode router: the policy
/// knobs, the per-step union, the gathered weight slices, and the
/// dispatch counters.  Lives inside `DecodeScratch`; every buffer grows
/// to its high-water mark and is then reused allocation-free, matching
/// the decode hot loop's zero-allocation contract.
pub struct RouteScratch {
    /// routing considered at all (from `ServePolicy.route_density > 0`)
    pub enabled: bool,
    /// route when `union / d_ff <= max_density` (at the threshold the
    /// routed path runs — the boundary is deterministic)
    pub max_density: f32,
    /// set per step by the model: true iff every span in the feed is a
    /// single token (pure decode).  A ragged prefill span unions whole
    /// prompt chunks into the gate and densifies the union, so mixed
    /// feeds always take the fused fallback.
    pub decode_step: bool,
    /// sorted (ascending) union of active global columns, length U
    cols: Vec<u16>,
    /// global column -> union position; `u32::MAX` marks "not in the
    /// union" between steps
    pos: Vec<u32>,
    /// gathered `W_u^T` rows, (U, K)
    wu_g: Mat,
    /// gathered `W_d` rows, (U, K)
    wd_g: Mat,
    /// dense up activations over the union, (m, U)
    ug: Mat,
    /// per-row packed union positions, ascending within each row
    row_pos: Vec<u32>,
    /// per-row packed gate values; scaled in place into coefficients
    row_val: Vec<f32>,
    /// row r's packed span is `row_bounds[r]..row_bounds[r + 1]`
    row_bounds: Vec<usize>,
    /// dispatch counters, drained by the serving loop
    pub stats: RouteStats,
}

impl RouteScratch {
    /// A disabled router for a model with `d_ff` FFN columns and
    /// `d_model` embedding width.  Buffers start empty and grow lazily
    /// on first routed step, so callers that never enable routing pay
    /// nothing beyond the `pos` map.
    pub fn new(d_ff: usize, d_model: usize) -> RouteScratch {
        RouteScratch {
            enabled: false,
            max_density: DEFAULT_ROUTE_DENSITY,
            decode_step: false,
            cols: Vec::new(),
            pos: vec![u32::MAX; d_ff],
            wu_g: Mat::zeros(0, d_model.max(1)),
            wd_g: Mat::zeros(0, d_model.max(1)),
            ug: Mat::zeros(0, 1),
            row_pos: Vec::new(),
            row_val: Vec::new(),
            row_bounds: Vec::new(),
            stats: RouteStats::default(),
        }
    }

    /// Number of columns in the current union.
    pub fn union_len(&self) -> usize {
        self.cols.len()
    }
}

/// Position-map mark for "column active somewhere in the batch but not
/// yet assigned a union position".
const SEEN: u32 = u32::MAX - 1;

/// Build the batch union from a packed gate: fills the scratch's
/// sorted union `cols`, the column→position map, and every row's
/// packed (position, value) list.  Returns the union size U.
///
/// TwELL packs each row's entries ascending by global column (tiles
/// ascending, slots within a tile ascending), so marking columns and
/// then scanning `pos` in column order yields a sorted union, and each
/// row's position list is automatically ascending — the invariant the
/// routed kernel's accumulation order (and hence bit-exactness with
/// the fused path) rests on.
pub fn build_union(hg: &TwellMatrix, rs: &mut RouteScratch) -> usize {
    let n = hg.n;
    let RouteScratch {
        cols,
        pos,
        row_pos,
        row_val,
        row_bounds,
        ..
    } = rs;
    if pos.len() < n {
        pos.resize(n, u32::MAX);
    }
    // un-mark the previous step's union (cols is exactly the set of
    // marked entries, so this is O(U_prev), not O(d_ff))
    for &c in cols.iter() {
        pos[c as usize] = u32::MAX;
    }
    cols.clear();
    row_pos.clear();
    row_val.clear();
    row_bounds.clear();
    row_bounds.push(0);
    for r in 0..hg.m {
        for (idx, _) in hg.row_entries(r) {
            pos[idx as usize] = SEEN;
        }
    }
    for (c, p) in pos[..n].iter_mut().enumerate() {
        if *p == SEEN {
            *p = cols.len() as u32;
            cols.push(c as u16);
        }
    }
    for r in 0..hg.m {
        for (idx, v) in hg.row_entries(r) {
            row_pos.push(pos[idx as usize]);
            row_val.push(v);
        }
        row_bounds.push(row_pos.len());
    }
    cols.len()
}

/// The routed FFN tail: gather the union slice of `W_u^T` / `W_d`,
/// run the up projection as a dense skinny GEMM over it, and
/// accumulate the down projection over each row's active positions.
/// Requires [`build_union`] to have run on this scratch for the same
/// gate.  Bit-exact with `fused::fused_up_down_into` (module docs).
///
/// An empty union short-circuits after zeroing `y` without reading a
/// single weight element.
pub fn routed_up_down_into(
    x: &Mat,
    rs: &mut RouteScratch,
    wu_t: &Mat,
    wd: &Mat,
    y: &mut Mat,
) {
    let (m, k) = (x.rows, x.cols);
    assert_eq!(wu_t.cols, k);
    assert_eq!(wd.cols, k);
    assert_eq!(wu_t.rows, wd.rows);
    assert_eq!((y.rows, y.cols), (m, k));
    assert_eq!(rs.row_bounds.len(), m + 1, "build_union not run for x");
    y.data.fill(0.0);
    let u = rs.cols.len();
    if u == 0 {
        return;
    }
    let RouteScratch {
        cols,
        wu_g,
        wd_g,
        ug,
        row_pos,
        row_val,
        row_bounds,
        ..
    } = rs;

    // ---- gather: bit-copy the union's weight rows, row-parallel ----
    wu_g.set_shape(u, k);
    wd_g.set_shape(u, k);
    {
        let wu_ptr = par::SendPtr::new(wu_g.data.as_mut_ptr());
        let wd_ptr = par::SendPtr::new(wd_g.data.as_mut_ptr());
        par::for_col_blocks(u, 2 * k, |lo, hi| {
            for (off, &src) in cols[lo..hi].iter().enumerate() {
                let s = src as usize * k;
                // SAFETY: destination rows `lo..hi` belong to exactly
                // one worker; sources are read-only
                unsafe {
                    std::ptr::copy_nonoverlapping(
                        wu_t.data.as_ptr().add(s),
                        wu_ptr.get().add((lo + off) * k),
                        k,
                    );
                    std::ptr::copy_nonoverlapping(
                        wd.data.as_ptr().add(s),
                        wd_ptr.get().add((lo + off) * k),
                        k,
                    );
                }
            }
        });
    }

    // ---- up projection: dense skinny GEMM over the gathered slice.
    // matmul_nt_into computes each element as one independent
    // dense::dot — identical to the fused kernel's implicit h_u.
    ug.set_shape(m, u);
    dense::matmul_nt_into(x, wu_g, ug);

    // ---- coefficients: scale each row's gate values by its ug
    // entries (eq. 3's `v * u`, same product as the fused kernel)
    for r in 0..m {
        let urow = ug.row(r);
        let (lo, hi) = (row_bounds[r], row_bounds[r + 1]);
        for (v, &p) in row_val[lo..hi].iter_mut().zip(&row_pos[lo..hi]) {
            *v *= urow[p as usize];
        }
    }

    // ---- down accumulation, column-parallel.  Each row walks ONLY
    // its active positions (ascending == the fused walk order);
    // inactive positions are skipped, never zero-multiplied, so the
    // result is bit-identical to the fused kernel.
    let wd_g = &*wd_g;
    let row_pos = &row_pos[..];
    let row_val = &row_val[..];
    let y_ptr = par::SendPtr::new(y.data.as_mut_ptr());
    par::for_col_blocks(k, row_val.len().max(1), |lo, hi| {
        for r in 0..m {
            // SAFETY: column ranges are disjoint per worker
            let yrow = unsafe {
                std::slice::from_raw_parts_mut(
                    y_ptr.get().add(r * k + lo),
                    hi - lo,
                )
            };
            let (rlo, rhi) = (row_bounds[r], row_bounds[r + 1]);
            let vals = &row_val[rlo..rhi];
            let poss = &row_pos[rlo..rhi];
            for (&coef, &p) in vals.iter().zip(poss) {
                dense::axpy(coef, &wd_g.row(p as usize)[lo..hi], yrow);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::fused::fused_up_down;
    use crate::sparse::twell::gate_matmul_twell;
    use crate::util::rng::Pcg32;

    /// Positive inputs + negatively shifted gate weights, the standard
    /// controllable-sparsity setup from the twell/fused tests.
    fn setup(
        m: usize,
        k: usize,
        n: usize,
        bias: f32,
        seed: u64,
    ) -> (Mat, Mat, Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let mut x = Mat::randn(m, k, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.05;
        }
        let mut wg = Mat::randn(k, n, 0.3, &mut rng);
        for v in wg.data.iter_mut() {
            *v -= bias / k as f32;
        }
        let wu = Mat::randn(k, n, 0.3, &mut rng);
        let wd = Mat::randn(n, k, 0.3, &mut rng);
        (x, wg, wu.transpose(), wd)
    }

    fn routed(
        x: &Mat,
        hg: &TwellMatrix,
        wu_t: &Mat,
        wd: &Mat,
        rs: &mut RouteScratch,
    ) -> Mat {
        let mut y = Mat::zeros(x.rows, x.cols);
        build_union(hg, rs);
        routed_up_down_into(x, rs, wu_t, wd, &mut y);
        y
    }

    #[test]
    fn union_matches_dense_reference() {
        let (x, wg, _, _) = setup(6, 16, 128, 4.0, 1);
        let hg = gate_matmul_twell(&x, &wg, 32, 1);
        let mut rs = RouteScratch::new(128, 16);
        let u = build_union(&hg, &mut rs);
        // reference union from the scattered-dense gate
        let dense_hg = hg.to_dense();
        let mut expect: Vec<u16> = (0..128u16)
            .filter(|&c| {
                (0..6).any(|r| dense_hg.at(r, c as usize) != 0.0)
            })
            .collect();
        expect.sort_unstable();
        assert_eq!(rs.cols, expect);
        assert_eq!(u, expect.len());
        // each row's positions are ascending and pair back to the
        // row's own packed (column, value) entries in order
        for r in 0..6 {
            let (lo, hi) = (rs.row_bounds[r], rs.row_bounds[r + 1]);
            let row = &rs.row_pos[lo..hi];
            assert!(row.windows(2).all(|w| w[0] < w[1]), "row {r} unsorted");
            let packed: Vec<(u16, f32)> = hg.row_entries(r).collect();
            assert_eq!(hi - lo, packed.len());
            for (i, &(idx, v)) in packed.iter().enumerate() {
                assert_eq!(rs.cols[rs.row_pos[lo + i] as usize], idx);
                assert_eq!(rs.row_val[lo + i], v);
            }
        }
    }

    /// The routed kernel must be bit-identical to the fused TwELL
    /// kernel for every thread count and dispatch shape — the property
    /// that makes routing invisible to the determinism suite.
    #[test]
    fn routed_bit_exact_with_fused_across_threads_and_dispatch() {
        let _g = par::test_guard();
        let orig = par::num_threads();
        // m < 32 with enough work that the pool paths genuinely engage
        let (x, wg, wu_t, wd) = setup(4, 128, 512, 4.0, 21);
        let hg = gate_matmul_twell(&x, &wg, 32, 1);
        let reference = {
            par::set_threads(1);
            par::set_skinny_fast_path(false);
            fused_up_down(&x, &hg, &wu_t, &wd).data
        };
        let mut rs = RouteScratch::new(512, 128);
        for &threads in &[1usize, 4] {
            for &fast in &[false, true] {
                par::set_threads(threads);
                par::set_skinny_fast_path(fast);
                let y = routed(&x, &hg, &wu_t, &wd, &mut rs);
                assert_eq!(
                    y.data, reference,
                    "routed diverged at t={threads} fast={fast}"
                );
            }
        }
        par::set_threads(orig);
        par::set_skinny_fast_path(true);
    }

    #[test]
    fn empty_union_short_circuits_without_reading_weights() {
        let (x, mut wg, mut wu_t, mut wd) = setup(4, 8, 32, 0.0, 3);
        for v in wg.data.iter_mut() {
            *v = -v.abs() - 0.1; // gate always negative => empty union
        }
        let hg = gate_matmul_twell(&x, &wg, 32, 1);
        assert_eq!(hg.total_nnz(), 0);
        // poison the weights: any read would propagate NaN
        wu_t.data.fill(f32::NAN);
        wd.data.fill(f32::NAN);
        let mut rs = RouteScratch::new(32, 8);
        let y = routed(&x, &hg, &wu_t, &wd, &mut rs);
        assert_eq!(rs.union_len(), 0);
        assert!(y.data.iter().all(|&v| v.to_bits() == 0.0f32.to_bits()));
    }

    #[test]
    fn scratch_reuse_big_then_small_matches_fresh() {
        let (xb, wgb, wu_tb, wdb) = setup(16, 16, 64, 0.0, 7);
        let hgb = gate_matmul_twell(&xb, &wgb, 32, 1);
        let mut rs = RouteScratch::new(64, 16);
        let _ = routed(&xb, &hgb, &wu_tb, &wdb, &mut rs);
        let (xs, wgs, wu_ts, wds) = setup(2, 16, 64, 6.0, 8);
        let hgs = gate_matmul_twell(&xs, &wgs, 32, 1);
        let reused = routed(&xs, &hgs, &wu_ts, &wds, &mut rs);
        let mut fresh_rs = RouteScratch::new(64, 16);
        let fresh = routed(&xs, &hgs, &wu_ts, &wds, &mut fresh_rs);
        assert_eq!(reused.data, fresh.data);
        assert_eq!(rs.cols, fresh_rs.cols);
    }

    #[test]
    fn dominant_label_and_mean_density() {
        let mut s = RouteStats::default();
        assert_eq!(s.dominant(), "routed"); // all-zero tie-break
        s.row = 3;
        s.routed = 3;
        assert_eq!(s.dominant(), "routed"); // tie prefers routed
        s.fallback = 5;
        assert_eq!(s.dominant(), "fallback");
        s.density_sum = 0.5;
        s.density_calls = 2;
        assert!((s.mean_density() - 0.25).abs() < 1e-12);
        let taken = s.take();
        assert_eq!(taken.fallback, 5);
        assert_eq!(s.density_calls, 0);
    }
}
