//! Whole feed-forward blocks: the two-kernel sparse inference pipeline
//! (section 3.3) and the hybrid-format training step with the paper's
//! eq. (4) backward (section 3.5) — plus their dense baselines.
//!
//! These are the units the benches time to regenerate figures 4/5 and the
//! forward/training columns of table 1.

use crate::metrics::memory::PeakTracker;
use crate::sparse::dense;
use crate::sparse::fused;
use crate::sparse::hybrid::HybridMatrix;
use crate::sparse::par;
use crate::sparse::route::{self, RouteScratch};
use crate::sparse::twell::{gate_matmul_twell, gate_matmul_twell_into,
                           TwellMatrix};
use crate::tensor::Mat;

/// Weights of one gated FFN block, with the transposed copies the sparse
/// kernels consume (appendix A.1 stores W_u transposed for coalescing).
#[derive(Clone)]
pub struct FfnWeights {
    pub wg: Mat,   // (K, N)
    pub wu: Mat,   // (K, N)
    pub wd: Mat,   // (N, K)
    pub wu_t: Mat, // (N, K)
    pub wg_t: Mat, // (N, K)
    pub tile_n: usize,
    pub comp: usize,
    pub ell_width: usize,
    pub tail_frac: f64,
}

impl FfnWeights {
    pub fn new(
        wg: Mat, wu: Mat, wd: Mat, tile_n: usize, comp: usize,
        ell_width: usize, tail_frac: f64,
    ) -> Self {
        let wu_t = wu.transpose();
        let wg_t = wg.transpose();
        FfnWeights { wg, wu, wd, wu_t, wg_t, tile_n, comp, ell_width, tail_frac }
    }

    pub fn random(
        k: usize, n: usize, std: f32, rng: &mut crate::util::rng::Pcg32,
        tile_n: usize, comp: usize, ell_width: usize, tail_frac: f64,
    ) -> Self {
        Self::new(
            Mat::randn(k, n, std, rng),
            Mat::randn(k, n, std, rng),
            Mat::randn(n, k, std, rng),
            tile_n,
            comp,
            ell_width,
            tail_frac,
        )
    }

    fn tail_rows(&self, m: usize) -> usize {
        ((m as f64 * self.tail_frac).ceil() as usize).max(1)
    }
}

/// Dense inference baseline (three GEMMs + elementwise).
pub fn forward_dense(w: &FfnWeights, x: &Mat) -> Mat {
    dense::gated_ffn(x, &w.wg, &w.wu, &w.wd)
}

/// Sparse inference pipeline: exactly two "kernel launches" (section 3.3)
/// — gate matmul with TwELL epilogue, then the fused up+down projection.
/// Returns the output and the TwELL gate activations (for statistics).
pub fn forward_twell(w: &FfnWeights, x: &Mat) -> (Mat, TwellMatrix) {
    let hg = gate_matmul_twell(x, &w.wg, w.tile_n, w.comp);
    let y = fused::fused_up_down(x, &hg, &w.wu_t, &w.wd);
    (y, hg)
}

/// Backend dispatch for the decode paths (single-token and batched),
/// which do not collect gate statistics.  Both pipelines compute each
/// output row independently of the others, so the result is bit-exact
/// whether `x` carries one row or a whole slot pool's worth.
pub fn forward_backend(w: &FfnWeights, x: &Mat, twell: bool) -> Mat {
    if twell {
        forward_twell(w, x).0
    } else {
        forward_dense(w, x)
    }
}

/// Reusable FFN intermediates for the batched decode path: sized once
/// at the engine's maximum step rows, reshaped per call within the
/// buffers' high-water marks — the decode loop never allocates here.
///
/// Only the active backend's buffers are pre-sized (`twell` selects
/// which); an engine runs one backend for its lifetime, so carrying
/// both would double the scratch for nothing.  If the other backend is
/// ever used anyway, its buffers grow once on first use — a one-time
/// allocation, never a correctness issue.
pub struct FfnScratch {
    /// dense backend: gate activations (doubles as `h` after the
    /// elementwise product)
    pub hg: Mat,
    /// dense backend: up-projection activations
    pub hu: Mat,
    /// sparse backend: TwELL gate activations
    pub hg_tw: TwellMatrix,
    /// sparse backend: fused-kernel coefficients (one per packed slot)
    pub coef: Vec<f32>,
}

impl FfnScratch {
    pub fn new(
        max_rows: usize, d_ff: usize, tile_n: usize, comp: usize,
        twell: bool,
    ) -> FfnScratch {
        let dense_rows = if twell { 0 } else { max_rows };
        let tw_rows = if twell { max_rows } else { 0 };
        FfnScratch {
            hg: Mat::zeros(dense_rows, d_ff),
            hu: Mat::zeros(dense_rows, d_ff),
            hg_tw: TwellMatrix::with_capacity(tw_rows, d_ff, tile_n, comp),
            coef: vec![0.0; tw_rows * (d_ff / comp)],
        }
    }
}

/// `forward_backend` into a caller-owned output, with every
/// intermediate drawn from `s` — bit-exact with the allocating
/// dispatch (identical kernels, identical order).
pub fn forward_backend_into(
    w: &FfnWeights, x: &Mat, twell: bool, s: &mut FfnScratch, y: &mut Mat,
) {
    if twell {
        gate_matmul_twell_into(x, &w.wg, w.tile_n, w.comp, &mut s.hg_tw);
        fused::fused_up_down_into(
            x, &s.hg_tw, &w.wu_t, &w.wd, y, &mut s.coef,
        );
    } else {
        s.hg.set_rows(x.rows);
        s.hu.set_rows(x.rows);
        dense::matmul_relu_into(x, &w.wg, &mut s.hg);
        dense::matmul_into(x, &w.wu, &mut s.hu);
        for (hv, uv) in s.hg.data.iter_mut().zip(&s.hu.data) {
            *hv *= uv;
        }
        dense::matmul_into(&s.hg, &w.wd, y);
    }
}

/// The decode-step FFN entry point: `forward_backend_into` wrapped in
/// the batch-contextual router (`sparse::mod` docs draw the full
/// decision tree).
///
/// On the TwELL backend, for a **pure-decode** feed with routing
/// enabled, the packed gate's batch union of active columns is
/// measured; at union density `<= route.max_density` the routed
/// union-gather kernel runs, otherwise the fused TwELL kernel — two
/// bit-identical branches, so the threshold is purely a throughput
/// knob.  The boundary is deterministic: exactly-at-threshold routes.
/// Mixed feeds (a ragged prefill span in the batch) skip the union
/// measurement entirely — prefill rows densify the union, so they
/// count as `fallback` without paying for a doomed `build_union`.
/// Every call bumps exactly one `route.stats` counter.
pub fn forward_backend_step_into(
    w: &FfnWeights, x: &Mat, twell: bool, s: &mut FfnScratch,
    route: &mut RouteScratch, y: &mut Mat,
) {
    if twell && route.enabled {
        if route.decode_step {
            gate_matmul_twell_into(x, &w.wg, w.tile_n, w.comp, &mut s.hg_tw);
            let union = route::build_union(&s.hg_tw, route);
            let density = union as f32 / s.hg_tw.n.max(1) as f32;
            route.stats.density_sum += density as f64;
            route.stats.density_calls += 1;
            if density <= route.max_density {
                route.stats.routed += 1;
                route::routed_up_down_into(x, route, &w.wu_t, &w.wd, y);
            } else {
                route.stats.fallback += 1;
                fused::fused_up_down_into(
                    x, &s.hg_tw, &w.wu_t, &w.wd, y, &mut s.coef,
                );
            }
            return;
        }
        route.stats.fallback += 1;
        forward_backend_into(w, x, twell, s, y);
        return;
    }
    if par::skinny_col_dispatch(x.rows) {
        route.stats.col += 1;
    } else {
        route.stats.row += 1;
    }
    forward_backend_into(w, x, twell, s, y);
}

/// Gradients of one FFN block (weight grads in (N, K) "transposed"
/// layout where noted — cheap to produce from the sparse path and
/// layout-identical between the two implementations for comparison).
pub struct FfnGrads {
    pub dwg_t: Mat, // (N, K) = (dWg)^T
    pub dwu_t: Mat, // (N, K) = (dWu)^T
    pub dwd: Mat,   // (N, K)
    pub dx: Mat,    // (M, K)
    pub loss_l1: f64,
    pub nnz: u64,
    pub overflow: bool,
    pub peak_activation_bytes: u64,
}

/// Dense training step baseline: forward keeping all intermediates dense
/// + full dense backward (what the paper's non-sparse runs do).
pub fn train_step_dense(w: &FfnWeights, x: &Mat, dy: &Mat,
                        l1_coeff: f32) -> FfnGrads {
    let mut peak = PeakTracker::default();
    let m = x.rows;
    let n = w.wg.cols;
    // forward: h_g, h_u, h all materialized (3 dense M x N activations)
    let hg = dense::matmul_relu(x, &w.wg);
    let hu = dense::matmul(x, &w.wu);
    let mut h = hg.clone();
    for (hv, uv) in h.data.iter_mut().zip(&hu.data) {
        *hv *= uv;
    }
    peak.alloc(3 * (m * n * 4) as u64);
    let _y = dense::matmul(&h, &w.wd);
    // backward
    // ∇h = ∇y @ W_d^T: matmul_nt(a (M,K), b (N,K)) = a @ b^T, wd is (N,K)
    let mut dh = dense::matmul_nt(dy, &w.wd);
    for (g, &hv) in dh.data.iter_mut().zip(&h.data) {
        if hv != 0.0 {
            *g += l1_coeff * hv.signum();
        }
    }
    let mut dhu = dh.clone();
    for (g, &gv) in dhu.data.iter_mut().zip(&hg.data) {
        *g *= gv;
    }
    let mut dzg = dh;
    for (g, (&uv, &gv)) in dzg.data.iter_mut().zip(hu.data.iter().zip(&hg.data)) {
        *g = if gv > 0.0 { *g * uv } else { 0.0 };
    }
    let dwd = dense::matmul_tn(&h, dy); // (N, K)
    let dwu_t = dense::matmul_tn(&dhu, x); // (N, K) = (x^T dhu)^T
    let dwg_t = dense::matmul_tn(&dzg, x);
    let mut dx = dense::matmul_nt(&dhu, &w.wu); // wu is (K,N): need dhu @ wu^T
    // careful: matmul_nt(a (M,N), b (K,N)) -> a @ b^T (M,K): wu is (K,N) ✓
    let dx2 = dense::matmul_nt(&dzg, &w.wg);
    for (a, b) in dx.data.iter_mut().zip(&dx2.data) {
        *a += b;
    }
    let nnz = hg.nnz_positive() as u64;
    let l1: f64 = h.data.iter().map(|&v| v.abs() as f64).sum();
    FfnGrads {
        dwg_t,
        dwu_t,
        dwd,
        dx,
        loss_l1: l1,
        nnz,
        overflow: false,
        peak_activation_bytes: peak.peak,
    }
}

/// Hybrid-format training step (section 3.5): forward materializes h_g
/// straight into TwELL -> hybrid, h_u only at the sparsity pattern, and
/// the whole backward (eq. 4) runs through hybrid kernels — no dense
/// M x N activation ever exists.
pub fn train_step_hybrid(w: &FfnWeights, x: &Mat, dy: &Mat,
                         l1_coeff: f32) -> FfnGrads {
    let m = x.rows;
    let n = w.wg.cols;
    let tail = w.tail_rows(m);
    let mut peak = PeakTracker::default();

    // ---- forward ----
    let tw = gate_matmul_twell(x, &w.wg, w.tile_n, w.comp);
    peak.alloc(tw.bytes());
    let (hg, _l0, _l1_gate) = HybridMatrix::from_twell(&tw, w.ell_width, tail);
    peak.alloc(hg.bytes());
    drop(tw);
    let hu = hg.dense_to_hybrid_matmul(x, &w.wu_t); // h_u at pattern
    peak.alloc(hu.bytes());
    let h = hg.mul_same_pattern(&hu);
    peak.alloc(h.bytes());
    let l1 = h.l1_sum(); // paper eq. (2) regularizes |h|, not |h_g|
    let _y = h.matmul(&w.wd);

    // ---- backward (eq. 4), all through the stored sparsity pattern ----
    // ∇h = ∇y W_d^T at the pattern: b_t is W_d itself ((N,K) rows = cols
    // of W_d^T)
    let mut dh = hg.dense_to_hybrid_matmul(dy, &w.wd);
    dh.inject_l1_grad(&h, l1_coeff);
    let dhu = dh.mul_same_pattern(&hg); // ∇h ⊙ h_g
    let dzg = dh.mul_same_pattern(&hu); // ∇h ⊙ h_u (ReLU mask == pattern)
    // ∇W_d = h^T ∇y  — hybrid transpose + hybrid-to-dense matmul
    let t_width = w.ell_width;
    let t_tail = ((n as f64 * 0.25).ceil() as usize).max(1);
    let h_t = h.transpose(t_width, t_tail);
    peak.alloc(h_t.bytes());
    let dwd = h_t.matmul(dy);
    // ∇W_u^T = (x^T ∇h_u)^T = (∇h_u)^T x
    let dhu_t = dhu.transpose(t_width, t_tail);
    let dwu_t = dhu_t.matmul(x);
    // ∇W_g^T likewise from ∇z_g
    let dzg_t = dzg.transpose(t_width, t_tail);
    let dwg_t = dzg_t.matmul(x);
    // ∇x = ∇h_u W_u^T + ∇z_g W_g^T
    let mut dx = dhu.matmul(&w.wu_t);
    let dx2 = dzg.matmul(&w.wg_t);
    for (a, b) in dx.data.iter_mut().zip(&dx2.data) {
        *a += b;
    }
    let overflow = hg.overflow
        || h_t.overflow
        || dhu_t.overflow
        || dzg_t.overflow;
    FfnGrads {
        dwg_t,
        dwu_t,
        dwd,
        dx,
        loss_l1: l1,
        nnz: hg.row_nnz.iter().map(|&z| z as u64).sum(),
        overflow,
        peak_activation_bytes: peak.peak,
    }
}

/// Bench/analysis helper: build an FFN + input batch whose gate sparsity
/// is calibrated to `target_nnz` average non-zeros per token (the knob
/// figures 4/5 sweep).  Uses positive inputs + a bias-shifted gate and
/// binary-searches the shift.
pub fn synth_sparse_ffn(
    m: usize, k: usize, n: usize, target_nnz: f64, seed: u64,
    tile_n: usize, comp: usize, ell_width: usize, tail_frac: f64,
) -> (FfnWeights, Mat) {
    let mut rng = crate::util::rng::Pcg32::seeded(seed);
    let mut w = FfnWeights::random(k, n, 0.3, &mut rng, tile_n, comp,
                                   ell_width, tail_frac);
    let mut x = Mat::randn(m, k, 1.0, &mut rng);
    for v in x.data.iter_mut() {
        *v = v.abs() + 0.05;
    }
    let base_wg = w.wg.clone();
    let (mut lo, mut hi) = (0.0f32, 64.0f32);
    for _ in 0..24 {
        let bias = 0.5 * (lo + hi);
        let mut wg = base_wg.clone();
        for v in wg.data.iter_mut() {
            *v -= bias / k as f32;
        }
        let hg = dense::matmul_relu(&x, &wg);
        let nnz = hg.nnz_positive() as f64 / m as f64;
        if nnz > target_nnz {
            lo = bias;
        } else {
            hi = bias;
        }
    }
    let bias = 0.5 * (lo + hi);
    for v in w.wg.data.iter_mut() {
        *v -= bias / k as f32;
    }
    w.wg_t = w.wg.transpose();
    (w, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Pcg32;

    fn setup(m: usize, k: usize, n: usize, bias: f32, seed: u64)
        -> (FfnWeights, Mat, Mat) {
        setup_cfg(m, k, n, bias, seed, 1, n, 1.0)
    }

    /// Positive inputs + negatively shifted gate weights give a
    /// controllable expected gate sparsity (see twell.rs tests).
    fn setup_cfg(m: usize, k: usize, n: usize, bias: f32, seed: u64,
                 comp: usize, ell_width: usize, tail_frac: f64)
        -> (FfnWeights, Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let mut w = FfnWeights::random(k, n, 0.3, &mut rng, 32, comp,
                                       ell_width, tail_frac);
        for v in w.wg.data.iter_mut() {
            *v -= bias / k as f32;
        }
        w.wg_t = w.wg.transpose();
        let mut x = Mat::randn(m, k, 1.0, &mut rng);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.05;
        }
        let dy = Mat::randn(m, k, 1.0, &mut rng);
        (w, x, dy)
    }

    #[test]
    fn forward_backend_is_row_independent() {
        // the guarantee the batched decode path relies on: running B rows
        // at once is bit-identical to running each row alone
        let (w, x, _) = setup(6, 16, 64, 0.5, 9);
        for twell in [false, true] {
            let batched = forward_backend(&w, &x, twell);
            for r in 0..x.rows {
                let mut single = Mat::zeros(1, x.cols);
                single.row_mut(0).copy_from_slice(x.row(r));
                let y1 = forward_backend(&w, &single, twell);
                assert_eq!(y1.row(0), batched.row(r),
                           "row {r} diverges (twell={twell})");
            }
        }
    }

    #[test]
    fn forward_backend_into_matches_allocating_dispatch() {
        // the decode scratch path must be bit-exact with the
        // allocating path, including across reuse at shrinking batch
        // sizes (stale intermediates must never leak)
        let (w, x, _) = setup(6, 16, 64, 0.5, 19);
        for twell in [false, true] {
            let mut s = FfnScratch::new(6, 64, w.tile_n, w.comp, twell);
            let mut y = Mat::zeros(6, 16);
            forward_backend_into(&w, &x, twell, &mut s, &mut y);
            assert_eq!(y.data, forward_backend(&w, &x, twell).data,
                       "twell={twell}");
            // shrink to 2 rows through the same scratch
            let mut xs = Mat::zeros(2, 16);
            xs.data.copy_from_slice(&x.data[..32]);
            let mut ys = Mat::zeros(2, 16);
            forward_backend_into(&w, &xs, twell, &mut s, &mut ys);
            assert_eq!(ys.data, forward_backend(&w, &xs, twell).data,
                       "twell={twell} after reuse");
        }
    }

    #[test]
    fn step_into_routed_matches_unrouted_bitwise() {
        // routing on vs off must agree bit-for-bit on both backends —
        // the property that makes the router invisible to every other
        // parity test in the suite
        let (w, x, _) = setup(4, 16, 64, 2.0, 29);
        for twell in [false, true] {
            let mut s = FfnScratch::new(4, 64, w.tile_n, w.comp, twell);
            let mut plain = Mat::zeros(4, 16);
            forward_backend_into(&w, &x, twell, &mut s, &mut plain);
            for &density in &[0.0f32, 1.0] {
                let mut route = RouteScratch::new(64, 16);
                route.enabled = density > 0.0;
                route.max_density = density;
                route.decode_step = true;
                let mut y = Mat::zeros(4, 16);
                forward_backend_step_into(
                    &w, &x, twell, &mut s, &mut route, &mut y,
                );
                assert_eq!(y.data, plain.data,
                           "twell={twell} density={density}");
            }
        }
    }

    #[test]
    fn density_exactly_at_threshold_routes_deterministically() {
        let (w, x, _) = setup(4, 16, 64, 2.0, 31);
        let mut s = FfnScratch::new(4, 64, w.tile_n, w.comp, true);
        // measure the union once to place the threshold exactly on it
        let hg = gate_matmul_twell(&x, &w.wg, w.tile_n, w.comp);
        let mut probe = RouteScratch::new(64, 16);
        let union = crate::sparse::route::build_union(&hg, &mut probe);
        assert!(union > 0 && union < 64, "need a non-trivial union");
        let at = union as f32 / 64.0; // exactly representable: /2^6
        let mut y = Mat::zeros(4, 16);
        for (density, expect_routed) in
            [(at, true), ((union as f32 - 0.5) / 64.0, false)]
        {
            let mut route = RouteScratch::new(64, 16);
            route.enabled = true;
            route.max_density = density;
            route.decode_step = true;
            forward_backend_step_into(
                &w, &x, true, &mut s, &mut route, &mut y,
            );
            assert_eq!(route.stats.routed, u64::from(expect_routed));
            assert_eq!(route.stats.fallback, u64::from(!expect_routed));
            assert_eq!(route.stats.density_calls, 1);
            let measured = route.stats.density_sum as f32;
            assert_eq!(measured, at, "measured density drifted");
        }
    }

    #[test]
    fn step_counters_label_non_routed_calls() {
        let _g = par::test_guard();
        let orig_t = par::num_threads();
        let (w, x, _) = setup(4, 16, 64, 0.5, 37);
        // dense backend, skinny batch, pool available => `col`
        par::set_threads(4);
        par::set_skinny_fast_path(true);
        let mut s = FfnScratch::new(4, 64, w.tile_n, w.comp, false);
        let mut route = RouteScratch::new(64, 16);
        route.enabled = true; // routing never applies to dense backend
        route.decode_step = true;
        let mut y = Mat::zeros(4, 16);
        forward_backend_step_into(&w, &x, false, &mut s, &mut route, &mut y);
        assert_eq!(
            (route.stats.col, route.stats.row, route.stats.density_calls),
            (1, 0, 0)
        );
        // single-threaded => `row` (the seed sequential shape)
        par::set_threads(1);
        forward_backend_step_into(&w, &x, false, &mut s, &mut route, &mut y);
        assert_eq!((route.stats.col, route.stats.row), (1, 1));
        // twell backend with routing disabled also counts as row/col
        par::set_threads(4);
        let mut stw = FfnScratch::new(4, 64, w.tile_n, w.comp, true);
        route.enabled = false;
        forward_backend_step_into(&w, &x, true, &mut stw, &mut route, &mut y);
        assert_eq!((route.stats.col, route.stats.row), (2, 1));
        // twell + routing + mixed feed (not a pure decode step) =>
        // fallback without a density measurement
        route.enabled = true;
        route.decode_step = false;
        forward_backend_step_into(&w, &x, true, &mut stw, &mut route, &mut y);
        assert_eq!(route.stats.fallback, 1);
        assert_eq!(route.stats.density_calls, 0);
        par::set_threads(orig_t);
        par::set_skinny_fast_path(true);
    }

    #[test]
    fn forward_twell_matches_dense() {
        let (w, x, _) = setup(24, 16, 64, 0.0, 1);
        let yd = forward_dense(&w, &x);
        let (ys, hg) = forward_twell(&w, &x);
        assert!(!hg.overflow);
        assert!(ys.rel_err(&yd) < 1e-4, "{}", ys.rel_err(&yd));
    }

    #[test]
    fn hybrid_backward_matches_dense_backward() {
        let (w, x, dy) = setup(24, 16, 64, 0.5, 2);
        let gd = train_step_dense(&w, &x, &dy, 0.0);
        let gh = train_step_hybrid(&w, &x, &dy, 0.0);
        assert!(!gh.overflow);
        assert!(gh.dwd.rel_err(&gd.dwd) < 1e-3, "dwd {}", gh.dwd.rel_err(&gd.dwd));
        assert!(gh.dwu_t.rel_err(&gd.dwu_t) < 1e-3, "dwu {}", gh.dwu_t.rel_err(&gd.dwu_t));
        assert!(gh.dwg_t.rel_err(&gd.dwg_t) < 1e-3, "dwg {}", gh.dwg_t.rel_err(&gd.dwg_t));
        assert!(gh.dx.rel_err(&gd.dx) < 1e-3, "dx {}", gh.dx.rel_err(&gd.dx));
        assert_eq!(gh.nnz, gd.nnz);
        assert!((gh.loss_l1 - gd.loss_l1).abs() / gd.loss_l1.max(1e-9) < 1e-3);
    }

    #[test]
    fn l1_injection_consistent_between_paths() {
        let (w, x, dy) = setup(16, 8, 32, 0.5, 3);
        let gd = train_step_dense(&w, &x, &dy, 0.1);
        let gh = train_step_hybrid(&w, &x, &dy, 0.1);
        assert!(gh.dwd.rel_err(&gd.dwd) < 1e-3);
        assert!(gh.dx.rel_err(&gd.dx) < 1e-3);
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        // spot-check dWg via central differences on a scalar loss
        let (w, x, _) = setup(6, 4, 32, 0.3, 4);
        let dy = Mat::from_vec(6, 4, vec![1.0; 24]); // loss = sum(y)
        let g = train_step_dense(&w, &x, &dy, 0.0);
        let eps = 1e-3;
        for &(kk, nn) in &[(0usize, 0usize), (1, 5), (3, 31), (2, 17)] {
            let mut wp = w.clone();
            *wp.wg.at_mut(kk, nn) += eps;
            let yp: f32 = forward_dense(&wp, &x).data.iter().sum();
            let mut wm = w.clone();
            *wm.wg.at_mut(kk, nn) -= eps;
            let ym: f32 = forward_dense(&wm, &x).data.iter().sum();
            let fd = (yp - ym) / (2.0 * eps);
            let an = g.dwg_t.at(nn, kk);
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs()),
                "dWg[{kk},{nn}] fd={fd} an={an}"
            );
        }
    }

    #[test]
    fn hybrid_peak_memory_below_dense_when_sparse() {
        // realistic compact sizing: comp=4, width 16, tail = m/8
        let (w, x, dy) = setup_cfg(64, 16, 128, 6.0, 5, 4, 16, 0.125);
        let gd = train_step_dense(&w, &x, &dy, 0.0);
        let gh = train_step_hybrid(&w, &x, &dy, 0.0);
        assert!(
            gh.peak_activation_bytes < gd.peak_activation_bytes,
            "{} !< {}",
            gh.peak_activation_bytes,
            gd.peak_activation_bytes
        );
    }

    #[test]
    fn prop_hybrid_grads_match_dense_across_sparsity() {
        check("hybrid training step == dense", 12, 29, |g: &mut Gen| {
            let m = 8 * g.usize_in(1, 3);
            let k = g.usize_in(4, 16);
            let n = 32 * g.usize_in(1, 2);
            let bias = g.f32_in(0.0, 6.0);
            let (w, x, dy) = setup(m, k, n, bias, g.rng.next_u64());
            let gd = train_step_dense(&w, &x, &dy, 0.01);
            let gh = train_step_hybrid(&w, &x, &dy, 0.01);
            if gh.overflow {
                return Err("unexpected overflow".into());
            }
            for (name, a, b) in [
                ("dwd", &gh.dwd, &gd.dwd),
                ("dwu", &gh.dwu_t, &gd.dwu_t),
                ("dwg", &gh.dwg_t, &gd.dwg_t),
                ("dx", &gh.dx, &gd.dx),
            ] {
                let err = a.rel_err(b);
                if err > 5e-3 {
                    return Err(format!("{name} rel err {err} ({m},{k},{n})"));
                }
            }
            Ok(())
        });
    }
}
