//! Engine counters and latency aggregation for the serving layer.
//!
//! Every shard engine owns one [`EngineStats`] behind its own mutex
//! (no cross-shard contention on the hot path); the `Server` facade
//! snapshots all of them and folds them with [`EngineStats::merge`],
//! so dashboards see one logical engine regardless of
//! `ServePolicy::shards`.  Merge semantics, field by field:
//!
//! * **Counters** (admissions, steps, FFN dispatch, …) **sum** — each
//!   shard observed a disjoint subset of the traffic.
//! * **Gauges** (`max_active`, `queue_peak`) take the **max** — a peak
//!   across shards is the largest peak any shard (or the shared
//!   admission queue) saw, not their sum.
//! * **Histograms** (`latency_hist`) add **element-wise**, which is
//!   exactly the histogram of the concatenated per-shard samples
//!   (`util::stats::merge_histograms` is the same identity for the
//!   analysis-side `Vec` histograms).
//! * **Queue-scope counters** (`shed_busy`, `queue_rejections`) are
//!   counted at the shared admission queue, not on any shard; the
//!   facade stamps the same value onto every shard snapshot (like
//!   `queue_peak`) and the merge takes the **max**, so the merged view
//!   reports the true count instead of `shards ×` it.
//!
//! The merged-equals-sum/max contract is pinned by the tests below and
//! by the live `Server::stats` vs `Server::shard_stats` test in
//! `serve::tests`.

use crate::serve::Completion;

/// Number of latency histogram buckets on [`EngineStats`].  Bucket `i`
/// counts completions whose `total_ms` fell in `[2^(i-1), 2^i)` ms
/// (bucket 0 is `< 1 ms`); the last bucket is unbounded above.  A
/// fixed-size array keeps `EngineStats` `Copy` and makes the merge a
/// branch-free element-wise add.
pub const LATENCY_BUCKETS: usize = 12;

/// Engine counters, exposed for tests and the serve CLI.  One instance
/// per shard engine; [`EngineStats::merge`] folds shards together.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EngineStats {
    /// requests admitted into a KV slot (per shard: the shard's share
    /// of the traffic; merged: the total)
    pub admissions: u64,
    /// admissions that landed while other sequences were mid-decode —
    /// i.e. backfills into a freed slot, the no-batch-barrier property
    pub backfilled: u64,
    /// batched engine steps executed
    pub steps: u64,
    /// prompt chunks fed (one per prefilling slot per engine step): a
    /// length-L prompt finishes prefill in `ceil(L / prefill_chunk)`
    /// chunks
    pub prefill_chunks: u64,
    /// requests retired early because the caller dropped every
    /// receiver; their KV blocks returned to the pool immediately
    pub abandoned: u64,
    /// most simultaneously active slots observed (gauge: merge takes
    /// the max across shards, since each shard has its own slot pool)
    pub max_active: usize,
    /// peak depth of the shared admission queue (gauge).  The queue is
    /// shared by every shard, so each shard snapshot carries the same
    /// value and the merge's max preserves it.
    pub queue_peak: usize,
    /// requests routed through the (removed) sequential fallback —
    /// always 0 since the paged cache serves any request that fits the
    /// pool; kept so dashboards and the acceptance checks can assert it
    pub fallbacks: u64,
    /// FFN layer-steps dispatched row-parallel (tall batches)
    pub ffn_row: u64,
    /// FFN layer-steps dispatched column-parallel (skinny batches)
    pub ffn_col: u64,
    /// FFN layer-steps executed by the routed union-gathered kernel
    pub ffn_routed: u64,
    /// FFN layer-steps where routing was considered but fell back to
    /// the fused row path (union too dense, or a mixed
    /// prefill+decode feed)
    pub ffn_fallback: u64,
    /// sum of measured union densities (over `union_density_calls`
    /// pure-decode routing decisions); see `mean_union_density`
    pub union_density_sum: f64,
    /// number of union-density measurements folded into
    /// `union_density_sum`
    pub union_density_calls: u64,
    /// admissions whose prompt prefix was (partly) served from the
    /// prefix cache — attached blocks and/or a copy-on-write copy
    pub prefix_hits: u64,
    /// full KV blocks attached by refcount instead of recomputed,
    /// summed over admissions
    pub prefix_blocks_shared: u64,
    /// copy-on-write block copies performed at admission (the first
    /// divergent or partially-matched block of a prefix hit)
    pub cow_copies: u64,
    /// most KV blocks held by live sequences at once on this shard's
    /// pool (gauge: merge takes the max — each shard owns its pool)
    pub kv_blocks_peak: usize,
    /// queued requests shed by an admission scan because their
    /// deadline had already passed or the remaining budget could not
    /// cover the estimated prefill+decode (never admitted; no KV was
    /// ever reserved for them)
    pub shed_deadline: u64,
    /// blocking submits that gave up waiting for queue space
    /// (`max_queue_wait` expired with the queue still full).  Queue-
    /// scope like `queue_peak`: every shard snapshot carries the same
    /// value and the merge's max preserves it.
    pub shed_busy: u64,
    /// in-flight sequences aborted mid-decode at their deadline; their
    /// partial tokens were delivered and their KV blocks freed
    pub deadline_aborts: u64,
    /// shard engine loops restarted by the panic supervisor — each one
    /// is a shard that panicked, failed its in-flight requests with
    /// `FinishReason::ShardFailed`, and came back with a fresh pool
    pub shard_restarts: u64,
    /// non-blocking submits refused with `SubmitError::Busy` because
    /// the queue was at `max_queue`.  Queue-scope (see `shed_busy`).
    pub queue_rejections: u64,
    /// power-of-two request-latency histogram over `total_ms`: bucket
    /// `i` counts completions in `[2^(i-1), 2^i)` ms (see
    /// [`LATENCY_BUCKETS`]); merged element-wise across shards
    pub latency_hist: [u64; LATENCY_BUCKETS],
}

impl EngineStats {
    /// Mean batch-union FFN column density over every pure-decode
    /// routing decision, or 0 when routing never measured one.
    pub fn mean_union_density(&self) -> f64 {
        if self.union_density_calls == 0 {
            0.0
        } else {
            self.union_density_sum / self.union_density_calls as f64
        }
    }

    /// Fold one completed request's latency into `latency_hist`.
    ///
    /// Hardened against clock anomalies: a NaN sample is dropped (with
    /// a debug assertion — it means a timestamp was fabricated
    /// upstream) and a negative sample clamps to 0 (a backwards clock
    /// step is still a "fast" completion).  Both used to land silently
    /// in bucket 0, corrupting the histogram.
    pub fn record_latency(&mut self, total_ms: f64) {
        if total_ms.is_nan() {
            debug_assert!(false, "NaN latency sample");
            return;
        }
        let total_ms = total_ms.max(0.0);
        let mut b = 0usize;
        while b + 1 < LATENCY_BUCKETS
            && total_ms >= (1u64 << b) as f64
        {
            b += 1;
        }
        self.latency_hist[b] += 1;
    }

    /// Total completions folded into `latency_hist`.
    pub fn latency_samples(&self) -> u64 {
        self.latency_hist.iter().sum()
    }

    /// Fold another shard's stats into this one: counters and
    /// histograms sum, gauges take the max (see the module docs).
    pub fn merge(&mut self, other: &EngineStats) {
        self.admissions += other.admissions;
        self.backfilled += other.backfilled;
        self.steps += other.steps;
        self.prefill_chunks += other.prefill_chunks;
        self.abandoned += other.abandoned;
        self.fallbacks += other.fallbacks;
        self.ffn_row += other.ffn_row;
        self.ffn_col += other.ffn_col;
        self.ffn_routed += other.ffn_routed;
        self.ffn_fallback += other.ffn_fallback;
        self.union_density_sum += other.union_density_sum;
        self.union_density_calls += other.union_density_calls;
        self.prefix_hits += other.prefix_hits;
        self.prefix_blocks_shared += other.prefix_blocks_shared;
        self.cow_copies += other.cow_copies;
        self.shed_deadline += other.shed_deadline;
        self.deadline_aborts += other.deadline_aborts;
        self.shard_restarts += other.shard_restarts;
        // queue-scope counters: the queue belongs to no single shard,
        // so every snapshot carries the same value — max preserves it
        // (summing would multiply it by the shard count)
        self.shed_busy = self.shed_busy.max(other.shed_busy);
        self.queue_rejections =
            self.queue_rejections.max(other.queue_rejections);
        self.max_active = self.max_active.max(other.max_active);
        self.queue_peak = self.queue_peak.max(other.queue_peak);
        self.kv_blocks_peak = self.kv_blocks_peak.max(other.kv_blocks_peak);
        for (a, b) in
            self.latency_hist.iter_mut().zip(&other.latency_hist)
        {
            *a += b;
        }
    }

    /// Merge a whole shard set into one aggregate view.
    pub fn merged(shards: &[EngineStats]) -> EngineStats {
        let mut out = EngineStats::default();
        for s in shards {
            out.merge(s);
        }
        out
    }
}

/// Latency/throughput aggregation for the serving example + benches.
#[derive(Default, Debug)]
pub struct ServeMetrics {
    pub completions: Vec<Completion>,
}

impl ServeMetrics {
    pub fn record(&mut self, c: Completion) {
        self.completions.push(c);
    }

    pub fn p50_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::median(&l))
            .unwrap_or(0.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::percentile(&l, 95.0))
            .unwrap_or(0.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::percentile(&l, 99.0))
            .unwrap_or(0.0)
    }

    /// Median time-to-first-token — the latency prefill chunking buys.
    pub fn p50_first_token_ms(&self) -> f64 {
        self.latencies(|c| c.first_token_ms)
            .map(|l| crate::util::stats::median(&l))
            .unwrap_or(0.0)
    }

    pub fn p95_first_token_ms(&self) -> f64 {
        self.latencies(|c| c.first_token_ms)
            .map(|l| crate::util::stats::percentile(&l, 95.0))
            .unwrap_or(0.0)
    }

    pub fn throughput_tok_s(&self, wall_s: f64) -> f64 {
        let toks: usize = self
            .completions
            .iter()
            .map(|c| c.tokens.len() + c.prefill_tokens)
            .sum();
        toks as f64 / wall_s
    }

    fn latencies(&self, f: impl Fn(&Completion) -> f64) -> Option<Vec<f64>> {
        if self.completions.is_empty() {
            return None;
        }
        Some(self.completions.iter().map(f).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_a() -> EngineStats {
        let mut s = EngineStats {
            admissions: 3,
            backfilled: 1,
            steps: 10,
            prefill_chunks: 4,
            abandoned: 1,
            max_active: 2,
            queue_peak: 5,
            fallbacks: 0,
            ffn_row: 7,
            ffn_col: 2,
            ffn_routed: 6,
            ffn_fallback: 3,
            union_density_sum: 0.5,
            union_density_calls: 6,
            prefix_hits: 2,
            prefix_blocks_shared: 8,
            cow_copies: 1,
            kv_blocks_peak: 5,
            shed_deadline: 2,
            shed_busy: 4,
            deadline_aborts: 1,
            shard_restarts: 1,
            queue_rejections: 4,
            ..EngineStats::default()
        };
        s.record_latency(0.5);
        s.record_latency(3.0);
        s
    }

    fn shard_b() -> EngineStats {
        let mut s = EngineStats {
            admissions: 5,
            backfilled: 2,
            steps: 20,
            prefill_chunks: 6,
            abandoned: 0,
            max_active: 4,
            queue_peak: 3,
            fallbacks: 0,
            ffn_row: 1,
            ffn_col: 9,
            ffn_routed: 2,
            ffn_fallback: 1,
            union_density_sum: 0.25,
            union_density_calls: 2,
            prefix_hits: 1,
            prefix_blocks_shared: 3,
            cow_copies: 0,
            kv_blocks_peak: 9,
            shed_deadline: 3,
            // queue-scope: shard B's snapshot carries the same shared
            // queue values as shard A's (the facade stamps them)
            shed_busy: 4,
            deadline_aborts: 2,
            shard_restarts: 0,
            queue_rejections: 4,
            ..EngineStats::default()
        };
        s.record_latency(3.5);
        s.record_latency(4096.0);
        s
    }

    #[test]
    fn merge_sums_counters_and_maxes_gauges() {
        let m = EngineStats::merged(&[shard_a(), shard_b()]);
        // counters: sum of the shard counters
        assert_eq!(m.admissions, 8);
        assert_eq!(m.backfilled, 3);
        assert_eq!(m.steps, 30);
        assert_eq!(m.prefill_chunks, 10);
        assert_eq!(m.abandoned, 1);
        assert_eq!(m.ffn_row, 8);
        assert_eq!(m.ffn_col, 11);
        assert_eq!(m.ffn_routed, 8);
        assert_eq!(m.ffn_fallback, 4);
        assert_eq!(m.union_density_calls, 8);
        assert!((m.union_density_sum - 0.75).abs() < 1e-12);
        assert_eq!(m.prefix_hits, 3);
        assert_eq!(m.prefix_blocks_shared, 11);
        assert_eq!(m.cow_copies, 1);
        assert_eq!(m.shed_deadline, 5);
        assert_eq!(m.deadline_aborts, 3);
        assert_eq!(m.shard_restarts, 1);
        // gauges: max across shards, never the sum
        assert_eq!(m.max_active, 4);
        assert_eq!(m.queue_peak, 5);
        assert_eq!(m.kv_blocks_peak, 9);
        // queue-scope counters: every shard snapshot carries the same
        // shared-queue value — the merge must report it, not 2x it
        assert_eq!(m.shed_busy, 4);
        assert_eq!(m.queue_rejections, 4);
        assert_eq!(m.latency_samples(), 4);
    }

    #[test]
    fn merge_with_default_is_identity() {
        // an idle shard (all-zero stats) must not perturb the merge —
        // the empty-shard analogue of merging an empty histogram
        let a = shard_a();
        let m = EngineStats::merged(&[a, EngineStats::default()]);
        assert_eq!(m, a);
        assert_eq!(EngineStats::merged(&[]), EngineStats::default());
    }

    #[test]
    fn merged_latency_hist_equals_hist_of_concatenated_samples() {
        // recording all samples into one EngineStats must produce the
        // same histogram as recording them shard-by-shard and merging
        let xs = [0.2, 1.0, 1.9, 2.0, 700.0, 5000.0];
        let (a_half, b_half) = xs.split_at(3);
        let mut a = EngineStats::default();
        let mut b = EngineStats::default();
        for &x in a_half {
            a.record_latency(x);
        }
        for &x in b_half {
            b.record_latency(x);
        }
        let mut all = EngineStats::default();
        for &x in &xs {
            all.record_latency(x);
        }
        let m = EngineStats::merged(&[a, b]);
        assert_eq!(m.latency_hist, all.latency_hist);
        assert_eq!(m.latency_samples(), xs.len() as u64);
    }

    #[test]
    fn latency_buckets_are_powers_of_two() {
        let mut s = EngineStats::default();
        s.record_latency(0.0); // < 1 ms → bucket 0
        s.record_latency(0.99);
        s.record_latency(1.0); // [1, 2) → bucket 1
        s.record_latency(2.0); // [2, 4) → bucket 2
        s.record_latency(1e9); // beyond every bound → last bucket
        assert_eq!(s.latency_hist[0], 2);
        assert_eq!(s.latency_hist[1], 1);
        assert_eq!(s.latency_hist[2], 1);
        assert_eq!(s.latency_hist[LATENCY_BUCKETS - 1], 1);
        assert_eq!(s.latency_samples(), 5);
    }

    #[test]
    fn negative_latency_clamps_to_the_fast_bucket() {
        // a backwards clock step must not corrupt the histogram: the
        // sample lands in bucket 0 (a "fast" completion), deliberately
        // — the same bucket 0.0 lands in
        let mut s = EngineStats::default();
        s.record_latency(-3.0);
        s.record_latency(-0.0);
        assert_eq!(s.latency_hist[0], 2);
        assert_eq!(s.latency_samples(), 2);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "NaN latency sample")]
    fn nan_latency_trips_the_debug_assertion() {
        EngineStats::default().record_latency(f64::NAN);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn nan_latency_is_dropped_in_release() {
        // release builds skip the sample entirely instead of filing it
        // in bucket 0
        let mut s = EngineStats::default();
        s.record_latency(f64::NAN);
        assert_eq!(s.latency_samples(), 0);
    }

    #[test]
    fn mean_union_density_of_merged_shards() {
        let m = EngineStats::merged(&[shard_a(), shard_b()]);
        // (0.5 + 0.25) / (6 + 2)
        assert!((m.mean_union_density() - 0.09375).abs() < 1e-12);
        assert_eq!(EngineStats::default().mean_union_density(), 0.0);
    }
}
