//! Per-shard engine loops: each shard engine thread runs one of these
//! functions for the lifetime of the `Server`.
//!
//! A shard owns its execution state outright — its `PagedKvCache`
//! (the full `policy.kv_blocks` pool), its slot vector, and its
//! zero-allocation `DecodeScratch` — and shares exactly two things
//! with the rest of the process: the `AdmissionQueue` it pulls
//! requests from, and the per-shard `EngineStats` mutex the facade
//! snapshots.  Nothing else crosses shard boundaries, which is why
//! adding shards multiplies capacity without adding synchronization
//! to the decode hot path.
//!
//! Compute-wise the shards are *not* independent: every kernel call
//! lands on the single process-global worker pool in `sparse::par`,
//! whose one job slot serializes concurrent steps (see "Per-shard
//! thread budgeting" in `par`'s docs).  That serialization is also
//! what keeps sharded serving bit-exact: each step runs the same
//! kernels over the same per-request state as a single-shard engine
//! would, and each request's seeded sampler consumes draws only for
//! its own tokens, so placement cannot perturb any stream.
//!
//! ## Panic isolation ([`run_shard`])
//!
//! The loops do not run bare on the shard thread: [`run_shard`] wraps
//! them in `catch_unwind`.  A panic anywhere inside an engine
//! iteration — a kernel panic re-raised off the worker pool, a bug in
//! the scheduler, an armed failpoint — unwinds to the supervisor,
//! which (1) drains the shard's *roster* (every request popped from
//! the queue but not yet answered, tracked from the instant the scan
//! claims it) and fails each one with a `FinishReason::ShardFailed`
//! completion, (2) bumps `shard_restarts`, and (3) re-enters the loop,
//! which rebuilds the `PagedKvCache`, slots and `DecodeScratch` from
//! scratch.  The other shards keep serving off the shared queue the
//! whole time, so the server degrades to N−1 shards during the
//! restart instead of stranding callers.  Every mutex the dead loop
//! may have poisoned (queue, stats, roster) is locked with poison
//! recovery — the guarded state is plain data a half-applied update
//! cannot corrupt (`EngineStats` is `Copy`; the roster maps ids to
//! channel ends).
//!
//! Lock order: the queue lock is taken first and the roster lock is a
//! leaf *inside the scan* (track-at-pop closes the window where a
//! popped-but-unanswered request could die untracked); the supervisor
//! takes the roster lock with no other lock held.  The stats lock
//! stays a leaf.  `catch_unwind` is confined to this file and the
//! worker pool (`sparse/par.rs`) by an `xtask check` rule, so the
//! panic-isolation policy stays auditable.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::model::kv::{kv_positions_needed, sample_decode, DecodeScratch,
                       PagedKvCache, PrefixAdmit};
use crate::model::sample::Sampler;
use crate::model::Model;

use super::admission::{AdmissionQueue, Pending, Wave};
use super::stats::EngineStats;
use super::{Completion, FinishReason, ServeMode, ServePolicy, Token};

/// What the supervisor needs to fail a request the dead loop was
/// holding: the caller's completion channel plus enough metadata for
/// an honest `Completion`.  Tracked from the moment the admission scan
/// pops the request, removed when its completion is sent (or the
/// request is dropped as abandoned).
pub(crate) struct InFlight {
    id: u64,
    tx: Sender<Completion>,
    enqueued: Instant,
    prefill_tokens: usize,
}

/// Shard-local map of popped-but-unanswered requests (see
/// [`InFlight`]).  Shared only between the loop and its supervisor —
/// never across shards.
pub(crate) type Roster = Arc<Mutex<HashMap<u64, InFlight>>>;

/// Poison-recovering stats lock: a shard that panicked mid-update
/// leaves counters at worst one event off, never structurally broken
/// (`EngineStats` is `Copy` plain data).
fn lock(stats: &Mutex<EngineStats>) -> MutexGuard<'_, EngineStats> {
    stats.lock().unwrap_or_else(|e| e.into_inner())
}

fn track(roster: &Roster, p: &Pending) {
    let mut g = roster.lock().unwrap_or_else(|e| e.into_inner());
    g.insert(p.req.id, InFlight {
        id: p.req.id,
        tx: p.tx.clone(),
        enqueued: p.enqueued,
        prefill_tokens: p.req.prompt.len(),
    });
}

fn untrack(roster: &Roster, id: u64) {
    roster.lock().unwrap_or_else(|e| e.into_inner()).remove(&id);
}

/// Send `p`'s completion and drop it from the roster — the one way a
/// tracked request leaves the shard.  The send is best-effort: an
/// abandoned caller's receiver is gone and the failure is harmless.
fn finish(
    roster: &Roster, tx: &Sender<Completion>, c: Completion,
) {
    untrack(roster, c.id);
    let _ = tx.send(c);
}

/// A queued request is hopeless when its deadline has already passed,
/// or when the per-position service-time estimate says the remaining
/// budget cannot cover its worst-case prefill+decode.  The estimate
/// warms up from observed retirements (EWMA); while cold, only the
/// already-passed check applies — shedding must never be speculative.
fn hopeless(p: &Pending, now: Instant, est_ms_per_pos: Option<f64>) -> bool {
    let Some(deadline) = p.deadline else { return false };
    if now >= deadline {
        return true;
    }
    // degenerate requests are answered instantly: never doomed
    if p.req.prompt.is_empty() || p.req.max_new == 0 {
        return false;
    }
    let Some(est) = est_ms_per_pos else { return false };
    let need = kv_positions_needed(p.req.prompt.len(), p.req.max_new);
    let remaining_ms = (deadline - now).as_secs_f64() * 1e3;
    remaining_ms < est * need as f64
}

/// Serve one request start-to-finish on the sequential path.
/// `queue_ms` was measured once, at dequeue.  Stats are recorded
/// *before* the completion is sent — the send releases the caller,
/// who may snapshot `Server::stats` immediately and must find this
/// request already counted.
fn serve_one(
    model: &Model, p: Pending, queue_ms: f64,
    stats: &Mutex<EngineStats>, roster: &Roster,
) {
    let mut first_token_ms = None;
    let tokens = sample_decode(model, &p.req.prompt, p.req.max_new,
                               p.req.params, |i, t| {
        if i == 0 {
            first_token_ms =
                Some(p.enqueued.elapsed().as_secs_f64() * 1e3);
        }
        if let Some(stream) = &p.stream {
            let _ = stream.send(Token { id: p.req.id, index: i, token: t });
        }
    });
    let total_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
    {
        let mut st = lock(stats);
        st.admissions += 1;
        st.record_latency(total_ms);
    }
    finish(roster, &p.tx, Completion {
        id: p.req.id,
        tokens,
        queue_ms,
        first_token_ms: first_token_ms.unwrap_or(total_ms),
        total_ms,
        prefill_tokens: p.req.prompt.len(),
        finish: FinishReason::Length,
    });
}

/// Legacy shard loop: collect a batch (waiting up to `max_wait` for it
/// to fill), then serve each request sequentially.  Deadlines are
/// enforced at dequeue only — `serve_one` is atomic, so there is no
/// mid-flight abort on this path (the continuous loop has one).
pub(crate) fn sequential_loop(
    model: Arc<Model>, queue: Arc<AdmissionQueue>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>, roster: &Roster,
) {
    while let Some(batch) =
        queue.collect_batch(policy.slots, policy.max_wait)
    {
        // queue time ends here, at dequeue — measured exactly once.
        // Tracking starts here too: the window between the queue's
        // drain and this loop is a few instructions with no kernel or
        // failpoint in it.
        let dequeued: Vec<(Pending, f64)> = batch
            .into_iter()
            .map(|p| {
                track(roster, &p);
                let q_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                (p, q_ms)
            })
            .collect();
        for (p, q_ms) in dequeued {
            if p.abandoned() {
                // every receiver is gone: nobody can observe a result
                lock(&stats).abandoned += 1;
                untrack(roster, p.req.id);
                continue;
            }
            if p.deadline.is_some_and(|d| Instant::now() >= d) {
                lock(&stats).shed_deadline += 1;
                let total_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                finish(roster, &p.tx, Completion {
                    id: p.req.id,
                    tokens: Vec::new(),
                    queue_ms: q_ms,
                    first_token_ms: total_ms,
                    total_ms,
                    prefill_tokens: p.req.prompt.len(),
                    finish: FinishReason::DeadlineExceeded,
                });
                continue;
            }
            serve_one(&model, p, q_ms, &stats, roster);
        }
    }
}

/// Per-slot state of an in-flight sequence.
struct Slot {
    p: Pending,
    queue_ms: f64,
    /// next prompt token index to feed (== prompt.len() once decoding)
    prompt_pos: usize,
    tokens: Vec<u32>,
    /// last sampled token, fed on the next iteration
    next_feed: u32,
    /// enqueue-to-first-sample latency, set when token 0 is chosen
    first_token_ms: Option<f64>,
    /// the request's private sampler (params + seeded RNG): one draw
    /// per sampled token, so the stream is independent of how other
    /// slots interleave with this one
    sampler: Sampler,
}

/// What the admission scan decided for each popped request, in pop
/// order.  Everything popped is resolved in the install phase — there
/// is no silent drop.
enum Plan {
    /// reserved a slot; carries the prefix-attach outcome
    Install(usize, PrefixAdmit),
    /// degenerate (empty prompt / max_new 0): answer empty, no slot
    Empty,
    /// every receiver dropped while queued: count + drop, no KV ever
    /// reserved (the whole-queue sweep claims these from any position)
    Abandoned,
    /// deadline passed or budget-doomed while queued: fail with
    /// `DeadlineExceeded`, no KV ever reserved
    ShedDeadline,
}

/// The continuous-batching shard loop over this shard's paged KV pool.
pub(crate) fn continuous_loop(
    model: Arc<Model>, queue: Arc<AdmissionQueue>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>, roster: &Roster,
) {
    let mut cache = PagedKvCache::new(
        &model, policy.slots, policy.kv_blocks, policy.kv_block_size,
    );
    cache.set_prefix_cache(policy.prefix_cache);
    let mut slots: Vec<Option<Slot>> =
        (0..policy.slots).map(|_| None).collect();
    let mut active = 0usize;
    let chunk = policy.prefill_chunk.max(1);
    // the zero-allocation decode scratch: every engine step's
    // activations, fused q|k|v, FFN intermediates and logits live in
    // these buffers for the lifetime of the shard
    let mut scratch =
        DecodeScratch::new(&model, policy.slots * chunk, policy.slots);
    // batch-contextual FFN routing policy (TwELL backend only): the
    // scratch owns the knobs, the union buffers and the dispatch
    // counters; the shard drains the counters into its `EngineStats`
    // after every step
    scratch.route.enabled = policy.route_density > 0.0;
    scratch.route.max_density = policy.route_density;
    // per-position service-time estimate (EWMA over retirements),
    // feeding the budget-doomed half of the deadline shed
    let mut est_ms_per_pos: Option<f64> = None;
    enum Admit {
        Take(Plan),
        /// worst case exceeds the whole pool: can never be served
        Reject,
        /// head of the queue waits for blocks / a slot to free up —
        /// on *this* shard; another shard's wave may still take it
        Wait,
    }
    loop {
        // ---- admission wave: pull queued requests in FIFO order
        // while this shard's block budget and slot pool cover them.
        // The scan runs under the queue lock and *performs* each
        // admission — `cache.admit` plans the prefix attach, charges
        // the unshared worst case, and copy-on-writes at most one
        // block — so the budget it checks is exactly the budget it
        // consumes (deterministic sequential work only: no kernels;
        // the roster lock is the scan's one leaf lock).  An idle
        // shard parks inside `poll` until work or shutdown arrives ----
        // lowest-index-first placement, as `position` gave before
        let mut free_si: Vec<usize> = (0..policy.slots)
            .rev()
            .filter(|&si| slots[si].is_none())
            .collect();
        let mut plans: Vec<Plan> = Vec::new();
        let now = Instant::now();
        let wave = queue.poll(active > 0, |items| {
            crate::fail_point!("admission-scan");
            let mut take = Vec::new();
            // whole-queue sweep first: abandoned and deadline-hopeless
            // requests are claimed from *any* position — they take no
            // slot or blocks, so they never wait behind a head that
            // does, and an abandoned entry can no longer linger
            // queued behind one
            let mut i = 0;
            while i < items.len() {
                let verdict = if items[i].abandoned() {
                    Some(Plan::Abandoned)
                } else if hopeless(&items[i], now, est_ms_per_pos) {
                    Some(Plan::ShedDeadline)
                } else {
                    None
                };
                match verdict {
                    Some(plan) => {
                        let p = items.remove(i).unwrap();
                        track(roster, &p);
                        plans.push(plan);
                        take.push(p);
                    }
                    None => i += 1,
                }
            }
            // then FIFO head admission under this shard's capacity
            loop {
                let decision = match items.front() {
                    None => break,
                    // degenerate requests take no slot or blocks, so
                    // they never have to wait for either
                    Some(p) if p.req.max_new == 0
                        || p.req.prompt.is_empty() =>
                    {
                        Admit::Take(Plan::Empty)
                    }
                    Some(p) => {
                        let positions = kv_positions_needed(
                            p.req.prompt.len(),
                            p.req.max_new,
                        );
                        if cache.blocks_for(positions) > cache.num_blocks
                        {
                            Admit::Reject
                        } else if let Some(&si) = free_si.last() {
                            match cache
                                .admit(si, &p.req.prompt, positions)
                            {
                                Ok(info) => {
                                    free_si.pop();
                                    Admit::Take(Plan::Install(si, info))
                                }
                                // over budget *after* sharing: wait
                                // for blocks to free up
                                Err(_) => Admit::Wait,
                            }
                        } else {
                            Admit::Wait
                        }
                    }
                };
                match decision {
                    Admit::Take(plan) => {
                        let p = items.pop_front().unwrap();
                        track(roster, &p);
                        plans.push(plan);
                        take.push(p);
                    }
                    Admit::Reject => {
                        // unreachable through submit (which validates
                        // against the pool), kept as a safety net so a
                        // broken invariant degrades to a dropped
                        // channel instead of an admission livelock
                        let p = items.pop_front().unwrap();
                        log::warn!(
                            "request {} needs more KV than the whole \
                             pool ({} blocks); rejecting",
                            p.req.id,
                            cache.num_blocks
                        );
                    }
                    Admit::Wait => break, // FIFO: keep arrival order
                }
            }
            take
        });
        let admitted = match wave {
            Wave::Admitted(v) => v,
            Wave::Stopped => return,
        };
        // a true backfill: some already-admitted sequence has made
        // progress, i.e. this wave lands mid-decode.  Computed against
        // the pre-wave state: installs from this same wave don't make
        // each other "backfills", even when a prefix hit starts one
        // mid-prompt.
        let backfill = slots.iter().flatten().any(|s| {
            s.prompt_pos > 0 || !s.tokens.is_empty()
        });
        for (p, plan) in admitted.into_iter().zip(plans) {
            // queue time ends here, at dequeue — measured exactly once
            let queue_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
            let total_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
            match plan {
                Plan::Abandoned => {
                    // claimed by the sweep: no KV was ever reserved
                    lock(&stats).abandoned += 1;
                    untrack(roster, p.req.id);
                    continue;
                }
                Plan::ShedDeadline => {
                    lock(&stats).shed_deadline += 1;
                    finish(roster, &p.tx, Completion {
                        id: p.req.id,
                        tokens: Vec::new(),
                        queue_ms,
                        first_token_ms: total_ms,
                        total_ms,
                        prefill_tokens: p.req.prompt.len(),
                        finish: FinishReason::DeadlineExceeded,
                    });
                    continue;
                }
                _ => {}
            }
            if p.abandoned() {
                // the caller vanished between the scan and this
                // install: release whatever the scan attached — don't
                // strand the slot or blocks
                if let Plan::Install(si, _) = plan {
                    cache.release_slot(si);
                }
                lock(&stats).abandoned += 1;
                untrack(roster, p.req.id);
                continue;
            }
            let Plan::Install(si, info) = plan else {
                // Plan::Empty — nothing to generate: an empty prompt
                // has no logits to sample (see `argmax`): empty
                // completion, no slot.  Stats land before the send
                // (see `serve_one`).
                lock(&stats).record_latency(total_ms);
                finish(roster, &p.tx, Completion {
                    id: p.req.id,
                    tokens: Vec::new(),
                    queue_ms,
                    first_token_ms: total_ms,
                    total_ms,
                    prefill_tokens: p.req.prompt.len(),
                    finish: FinishReason::Length,
                });
                continue;
            };
            debug_assert!(slots[si].is_none());
            let sampler = Sampler::new(p.req.params);
            slots[si] = Some(Slot {
                p,
                queue_ms,
                // chunked prefill skips straight past the prefix the
                // pool already held — on a full hit the very next step
                // feeds the final prompt token and samples
                prompt_pos: info.cached_positions,
                tokens: Vec::new(),
                next_feed: 0,
                first_token_ms: None,
                sampler,
            });
            active += 1;
            let mut st = lock(&stats);
            st.admissions += 1;
            if info.cached_positions > 0 {
                st.prefix_hits += 1;
            }
            st.prefix_blocks_shared += info.shared_blocks as u64;
            if info.cow_rows > 0 {
                st.cow_copies += 1;
            }
            if backfill {
                st.backfilled += 1;
            }
            st.max_active = st.max_active.max(active);
        }
        // ---- reap abandoned or deadline-passed sequences: decoding
        // on for a dead channel would only burn compute; decoding past
        // the deadline would burn it on an answer the caller already
        // wrote off.  Both free their KV blocks immediately -------------
        let now = Instant::now();
        for (si, entry) in slots.iter_mut().enumerate() {
            let Some(s) = entry.as_ref() else { continue };
            if s.p.abandoned() {
                let s = entry.take().unwrap();
                cache.release_slot(si);
                active -= 1;
                lock(&stats).abandoned += 1;
                // best-effort notification (the receiver is gone; the
                // send keeps the roster bookkeeping uniform)
                let total_ms =
                    s.p.enqueued.elapsed().as_secs_f64() * 1e3;
                finish(roster, &s.p.tx, Completion {
                    id: s.p.req.id,
                    tokens: s.tokens,
                    queue_ms: s.queue_ms,
                    first_token_ms: s.first_token_ms.unwrap_or(total_ms),
                    total_ms,
                    prefill_tokens: s.p.req.prompt.len(),
                    finish: FinishReason::Abandoned,
                });
            } else if s.p.deadline.is_some_and(|d| now >= d) {
                // in-flight deadline abort: deliver the partial stream
                // (whatever was already sampled) and free the blocks
                let s = entry.take().unwrap();
                cache.release_slot(si);
                active -= 1;
                lock(&stats).deadline_aborts += 1;
                let total_ms =
                    s.p.enqueued.elapsed().as_secs_f64() * 1e3;
                finish(roster, &s.p.tx, Completion {
                    id: s.p.req.id,
                    tokens: s.tokens,
                    queue_ms: s.queue_ms,
                    first_token_ms: s.first_token_ms.unwrap_or(total_ms),
                    total_ms,
                    prefill_tokens: s.p.req.prompt.len(),
                    finish: FinishReason::DeadlineExceeded,
                });
            }
        }
        if active == 0 {
            continue;
        }

        // ---- one batched engine step over every active slot: a
        // prefilling slot feeds its next prompt chunk (up to one KV
        // block by default), a decoding slot feeds its last sample ----
        crate::fail_point!("engine-step");
        let prefilling = slots
            .iter()
            .flatten()
            .filter(|s| s.prompt_pos < s.p.req.prompt.len())
            .count() as u64;
        let feeds: Vec<(usize, &[u32])> = slots
            .iter()
            .enumerate()
            .filter_map(|(si, s)| {
                s.as_ref().map(|s| {
                    let span: &[u32] =
                        if s.prompt_pos < s.p.req.prompt.len() {
                            let end = (s.prompt_pos + chunk)
                                .min(s.p.req.prompt.len());
                            &s.p.req.prompt[s.prompt_pos..end]
                        } else {
                            std::slice::from_ref(&s.next_feed)
                        };
                    (si, span)
                })
            })
            .collect();
        let logits =
            model.prefill_decode_step_into(&mut cache, &feeds, &mut scratch);
        let fed: Vec<(usize, usize)> =
            feeds.iter().map(|&(si, span)| (si, span.len())).collect();
        drop(feeds);
        {
            let mut st = lock(&stats);
            st.steps += 1;
            st.prefill_chunks += prefilling;
            st.kv_blocks_peak =
                st.kv_blocks_peak.max(cache.blocks_in_use());
            let r = scratch.route.stats.take();
            st.ffn_row += r.row;
            st.ffn_col += r.col;
            st.ffn_routed += r.routed;
            st.ffn_fallback += r.fallback;
            st.union_density_sum += r.density_sum;
            st.union_density_calls += r.density_calls;
        }

        // ---- sample / retire --------------------------------------------
        for (row, &(si, n_fed)) in fed.iter().enumerate() {
            let slot = slots[si].as_mut().unwrap();
            if slot.prompt_pos < slot.p.req.prompt.len() {
                slot.prompt_pos += n_fed;
                if slot.prompt_pos < slot.p.req.prompt.len() {
                    continue; // still prefilling
                }
                // the prompt's last logits arrive with its final
                // chunk: fall through and sample the first token
            }
            let next = slot.sampler.sample(logits.row(row)) as u32;
            let index = slot.tokens.len();
            if index == 0 {
                slot.first_token_ms =
                    Some(slot.p.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            slot.tokens.push(next);
            if let Some(stream) = &slot.p.stream {
                let _ = stream.send(Token {
                    id: slot.p.req.id,
                    index,
                    token: next,
                });
            }
            if slot.tokens.len() >= slot.p.req.max_new {
                // finished: retire immediately — blocks go back to the
                // free list and the slot backfills next iteration (no
                // batch barrier)
                let s = slots[si].take().unwrap();
                cache.release_slot(si);
                active -= 1;
                let total_ms =
                    s.p.enqueued.elapsed().as_secs_f64() * 1e3;
                // feed the deadline-doom estimator: service time per
                // position actually processed, smoothed
                let positions =
                    (s.p.req.prompt.len() + s.tokens.len()) as f64;
                if positions > 0.0 {
                    let per =
                        ((total_ms - s.queue_ms).max(0.0)) / positions;
                    est_ms_per_pos = Some(match est_ms_per_pos {
                        None => per,
                        Some(e) => 0.8 * e + 0.2 * per,
                    });
                }
                // stats land before the send (see `serve_one`)
                lock(&stats).record_latency(total_ms);
                finish(roster, &s.p.tx, Completion {
                    id: s.p.req.id,
                    tokens: s.tokens,
                    queue_ms: s.queue_ms,
                    first_token_ms: s.first_token_ms.unwrap_or(total_ms),
                    total_ms,
                    prefill_tokens: s.p.req.prompt.len(),
                    finish: FinishReason::Length,
                });
            } else {
                slot.next_feed = next;
            }
        }
    }
}

/// Shard supervisor: run the policy's loop under `catch_unwind`,
/// converting a shard panic into failed-but-answered requests and a
/// fresh restart instead of a silently dead thread (module docs have
/// the full protocol).  Returns only on clean shutdown.
pub(crate) fn run_shard(
    model: Arc<Model>, queue: Arc<AdmissionQueue>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>,
) {
    let roster: Roster = Arc::new(Mutex::new(HashMap::new()));
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            match policy.mode {
                ServeMode::Sequential => sequential_loop(
                    model.clone(), queue.clone(), policy, stats.clone(),
                    &roster,
                ),
                ServeMode::Continuous => continuous_loop(
                    model.clone(), queue.clone(), policy, stats.clone(),
                    &roster,
                ),
            }
        }));
        match outcome {
            // clean shutdown: the loop drained the queue and exited;
            // nothing can be left on the roster
            Ok(()) => return,
            Err(payload) => {
                log::error!(
                    "serve shard panicked ({}); failing its in-flight \
                     requests and restarting with a fresh KV pool",
                    panic_message(payload.as_ref())
                );
                let failed: Vec<InFlight> = {
                    let mut g = roster
                        .lock()
                        .unwrap_or_else(|e| e.into_inner());
                    g.drain().map(|(_, f)| f).collect()
                };
                lock(&stats).shard_restarts += 1;
                for f in failed {
                    let total_ms =
                        f.enqueued.elapsed().as_secs_f64() * 1e3;
                    // queue_ms is unknowable after the loop's state
                    // died with it; 0 keeps the ordering invariant
                    // queue_ms <= first_token_ms <= total_ms
                    let _ = f.tx.send(Completion {
                        id: f.id,
                        tokens: Vec::new(),
                        queue_ms: 0.0,
                        first_token_ms: total_ms,
                        total_ms,
                        prefill_tokens: f.prefill_tokens,
                        finish: FinishReason::ShardFailed,
                    });
                }
                // fall through: restart the loop with a fresh cache
            }
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&'static str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}
