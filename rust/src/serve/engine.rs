//! Per-shard engine loops: each shard engine thread runs one of these
//! functions for the lifetime of the `Server`.
//!
//! A shard owns its execution state outright — its `PagedKvCache`
//! (the full `policy.kv_blocks` pool), its slot vector, and its
//! zero-allocation `DecodeScratch` — and shares exactly two things
//! with the rest of the process: the `AdmissionQueue` it pulls
//! requests from, and the per-shard `EngineStats` mutex the facade
//! snapshots.  Nothing else crosses shard boundaries, which is why
//! adding shards multiplies capacity without adding synchronization
//! to the decode hot path.
//!
//! Compute-wise the shards are *not* independent: every kernel call
//! lands on the single process-global worker pool in `sparse::par`,
//! whose one job slot serializes concurrent steps (see "Per-shard
//! thread budgeting" in `par`'s docs).  That serialization is also
//! what keeps sharded serving bit-exact: each step runs the same
//! kernels over the same per-request state as a single-shard engine
//! would, and each request's seeded sampler consumes draws only for
//! its own tokens, so placement cannot perturb any stream.

use std::sync::{Arc, Mutex};

use crate::model::kv::{kv_positions_needed, sample_decode, DecodeScratch,
                       PagedKvCache, PrefixAdmit};
use crate::model::sample::Sampler;
use crate::model::Model;

use super::admission::{AdmissionQueue, Pending, Wave};
use super::stats::EngineStats;
use super::{Completion, ServePolicy, Token};

/// Serve one request start-to-finish on the sequential path.
/// `queue_ms` was measured once, at dequeue.  Stats are recorded
/// *before* the completion is sent — the send releases the caller,
/// who may snapshot `Server::stats` immediately and must find this
/// request already counted.
fn serve_one(
    model: &Model, p: Pending, queue_ms: f64,
    stats: &Mutex<EngineStats>,
) {
    let mut first_token_ms = None;
    let tokens = sample_decode(model, &p.req.prompt, p.req.max_new,
                               p.req.params, |i, t| {
        if i == 0 {
            first_token_ms =
                Some(p.enqueued.elapsed().as_secs_f64() * 1e3);
        }
        if let Some(stream) = &p.stream {
            let _ = stream.send(Token { id: p.req.id, index: i, token: t });
        }
    });
    let total_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
    {
        let mut st = stats.lock().unwrap();
        st.admissions += 1;
        st.record_latency(total_ms);
    }
    let _ = p.tx.send(Completion {
        id: p.req.id,
        tokens,
        queue_ms,
        first_token_ms: first_token_ms.unwrap_or(total_ms),
        total_ms,
        prefill_tokens: p.req.prompt.len(),
    });
}

/// Legacy shard loop: collect a batch (waiting up to `max_wait` for it
/// to fill), then serve each request sequentially.
pub(crate) fn sequential_loop(
    model: Arc<Model>, queue: Arc<AdmissionQueue>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>,
) {
    while let Some(batch) =
        queue.collect_batch(policy.slots, policy.max_wait)
    {
        // queue time ends here, at dequeue — measured exactly once
        let dequeued: Vec<(Pending, f64)> = batch
            .into_iter()
            .map(|p| {
                let q_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                (p, q_ms)
            })
            .collect();
        for (p, q_ms) in dequeued {
            if p.abandoned() {
                // every receiver is gone: nobody can observe a result
                stats.lock().unwrap().abandoned += 1;
                continue;
            }
            serve_one(&model, p, q_ms, &stats);
        }
    }
}

/// Per-slot state of an in-flight sequence.
struct Slot {
    p: Pending,
    queue_ms: f64,
    /// next prompt token index to feed (== prompt.len() once decoding)
    prompt_pos: usize,
    tokens: Vec<u32>,
    /// last sampled token, fed on the next iteration
    next_feed: u32,
    /// enqueue-to-first-sample latency, set when token 0 is chosen
    first_token_ms: Option<f64>,
    /// the request's private sampler (params + seeded RNG): one draw
    /// per sampled token, so the stream is independent of how other
    /// slots interleave with this one
    sampler: Sampler,
}

/// The continuous-batching shard loop over this shard's paged KV pool.
pub(crate) fn continuous_loop(
    model: Arc<Model>, queue: Arc<AdmissionQueue>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>,
) {
    let mut cache = PagedKvCache::new(
        &model, policy.slots, policy.kv_blocks, policy.kv_block_size,
    );
    cache.set_prefix_cache(policy.prefix_cache);
    let mut slots: Vec<Option<Slot>> =
        (0..policy.slots).map(|_| None).collect();
    let mut active = 0usize;
    let chunk = policy.prefill_chunk.max(1);
    // the zero-allocation decode scratch: every engine step's
    // activations, fused q|k|v, FFN intermediates and logits live in
    // these buffers for the lifetime of the shard
    let mut scratch =
        DecodeScratch::new(&model, policy.slots * chunk, policy.slots);
    // batch-contextual FFN routing policy (TwELL backend only): the
    // scratch owns the knobs, the union buffers and the dispatch
    // counters; the shard drains the counters into its `EngineStats`
    // after every step
    scratch.route.enabled = policy.route_density > 0.0;
    scratch.route.max_density = policy.route_density;
    enum Admit {
        /// answered or installed this wave; a `Some` carries the slot
        /// the scan reserved and the prefix-attach outcome
        Take(Option<(usize, PrefixAdmit)>),
        /// worst case exceeds the whole pool: can never be served
        Reject,
        /// head of the queue waits for blocks / a slot to free up —
        /// on *this* shard; another shard's wave may still take it
        Wait,
    }
    loop {
        // ---- admission wave: pull queued requests in FIFO order
        // while this shard's block budget and slot pool cover them.
        // The scan runs under the queue lock and *performs* each
        // admission — `cache.admit` plans the prefix attach, charges
        // the unshared worst case, and copy-on-writes at most one
        // block — so the budget it checks is exactly the budget it
        // consumes (deterministic sequential work only: no kernels,
        // no other locks).  An idle shard parks inside `poll` until
        // work or shutdown arrives -----------------------------------
        // lowest-index-first placement, as `position` gave before
        let mut free_si: Vec<usize> = (0..policy.slots)
            .rev()
            .filter(|&si| slots[si].is_none())
            .collect();
        let mut plans: Vec<Option<(usize, PrefixAdmit)>> = Vec::new();
        let wave = queue.poll(active > 0, |items| {
            let mut take = Vec::new();
            loop {
                let decision = match items.front() {
                    None => break,
                    // abandoned or degenerate requests take no slot or
                    // blocks, so they never have to wait for either
                    Some(p) if p.abandoned() => Admit::Take(None),
                    Some(p) if p.req.max_new == 0
                        || p.req.prompt.is_empty() =>
                    {
                        Admit::Take(None)
                    }
                    Some(p) => {
                        let positions = kv_positions_needed(
                            p.req.prompt.len(),
                            p.req.max_new,
                        );
                        if cache.blocks_for(positions) > cache.num_blocks
                        {
                            Admit::Reject
                        } else if let Some(&si) = free_si.last() {
                            match cache
                                .admit(si, &p.req.prompt, positions)
                            {
                                Ok(info) => {
                                    free_si.pop();
                                    Admit::Take(Some((si, info)))
                                }
                                // over budget *after* sharing: wait
                                // for blocks to free up
                                Err(_) => Admit::Wait,
                            }
                        } else {
                            Admit::Wait
                        }
                    }
                };
                match decision {
                    Admit::Take(plan) => {
                        plans.push(plan);
                        take.push(items.pop_front().unwrap());
                    }
                    Admit::Reject => {
                        // unreachable through submit (which validates
                        // against the pool), kept as a safety net so a
                        // broken invariant degrades to a dropped
                        // channel instead of an admission livelock
                        let p = items.pop_front().unwrap();
                        log::warn!(
                            "request {} needs more KV than the whole \
                             pool ({} blocks); rejecting",
                            p.req.id,
                            cache.num_blocks
                        );
                    }
                    Admit::Wait => break, // FIFO: keep arrival order
                }
            }
            take
        });
        let admitted = match wave {
            Wave::Admitted(v) => v,
            Wave::Stopped => return,
        };
        // a true backfill: some already-admitted sequence has made
        // progress, i.e. this wave lands mid-decode.  Computed against
        // the pre-wave state: installs from this same wave don't make
        // each other "backfills", even when a prefix hit starts one
        // mid-prompt.
        let backfill = slots.iter().flatten().any(|s| {
            s.prompt_pos > 0 || !s.tokens.is_empty()
        });
        for (p, plan) in admitted.into_iter().zip(plans) {
            // queue time ends here, at dequeue — measured exactly once
            let queue_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
            if p.abandoned() {
                // the caller vanished while the request was queued (or
                // between the scan and this install): release whatever
                // the scan attached — don't strand the slot or blocks
                if let Some((si, _)) = plan {
                    cache.release_slot(si);
                }
                stats.lock().unwrap().abandoned += 1;
                continue;
            }
            let Some((si, info)) = plan else {
                // nothing to generate — an empty prompt has no logits
                // to sample (see `argmax`): empty completion, no slot.
                // Stats land before the send (see `serve_one`).
                let total_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                stats.lock().unwrap().record_latency(total_ms);
                let _ = p.tx.send(Completion {
                    id: p.req.id,
                    tokens: Vec::new(),
                    queue_ms,
                    first_token_ms: total_ms,
                    total_ms,
                    prefill_tokens: p.req.prompt.len(),
                });
                continue;
            };
            debug_assert!(slots[si].is_none());
            let sampler = Sampler::new(p.req.params);
            slots[si] = Some(Slot {
                p,
                queue_ms,
                // chunked prefill skips straight past the prefix the
                // pool already held — on a full hit the very next step
                // feeds the final prompt token and samples
                prompt_pos: info.cached_positions,
                tokens: Vec::new(),
                next_feed: 0,
                first_token_ms: None,
                sampler,
            });
            active += 1;
            let mut st = stats.lock().unwrap();
            st.admissions += 1;
            if info.cached_positions > 0 {
                st.prefix_hits += 1;
            }
            st.prefix_blocks_shared += info.shared_blocks as u64;
            if info.cow_rows > 0 {
                st.cow_copies += 1;
            }
            if backfill {
                st.backfilled += 1;
            }
            st.max_active = st.max_active.max(active);
        }
        // ---- reap abandoned sequences: a caller that dropped every
        // receiver can never observe the result, so decoding on would
        // only burn compute and strand KV blocks --------------------------
        for (si, entry) in slots.iter_mut().enumerate() {
            if entry.as_ref().is_some_and(|s| s.p.abandoned()) {
                *entry = None;
                cache.release_slot(si);
                active -= 1;
                stats.lock().unwrap().abandoned += 1;
            }
        }
        if active == 0 {
            continue;
        }

        // ---- one batched engine step over every active slot: a
        // prefilling slot feeds its next prompt chunk (up to one KV
        // block by default), a decoding slot feeds its last sample ----
        let prefilling = slots
            .iter()
            .flatten()
            .filter(|s| s.prompt_pos < s.p.req.prompt.len())
            .count() as u64;
        let feeds: Vec<(usize, &[u32])> = slots
            .iter()
            .enumerate()
            .filter_map(|(si, s)| {
                s.as_ref().map(|s| {
                    let span: &[u32] =
                        if s.prompt_pos < s.p.req.prompt.len() {
                            let end = (s.prompt_pos + chunk)
                                .min(s.p.req.prompt.len());
                            &s.p.req.prompt[s.prompt_pos..end]
                        } else {
                            std::slice::from_ref(&s.next_feed)
                        };
                    (si, span)
                })
            })
            .collect();
        let logits =
            model.prefill_decode_step_into(&mut cache, &feeds, &mut scratch);
        let fed: Vec<(usize, usize)> =
            feeds.iter().map(|&(si, span)| (si, span.len())).collect();
        drop(feeds);
        {
            let mut st = stats.lock().unwrap();
            st.steps += 1;
            st.prefill_chunks += prefilling;
            st.kv_blocks_peak =
                st.kv_blocks_peak.max(cache.blocks_in_use());
            let r = scratch.route.stats.take();
            st.ffn_row += r.row;
            st.ffn_col += r.col;
            st.ffn_routed += r.routed;
            st.ffn_fallback += r.fallback;
            st.union_density_sum += r.density_sum;
            st.union_density_calls += r.density_calls;
        }

        // ---- sample / retire --------------------------------------------
        for (row, &(si, n_fed)) in fed.iter().enumerate() {
            let slot = slots[si].as_mut().unwrap();
            if slot.prompt_pos < slot.p.req.prompt.len() {
                slot.prompt_pos += n_fed;
                if slot.prompt_pos < slot.p.req.prompt.len() {
                    continue; // still prefilling
                }
                // the prompt's last logits arrive with its final
                // chunk: fall through and sample the first token
            }
            let next = slot.sampler.sample(logits.row(row)) as u32;
            let index = slot.tokens.len();
            if index == 0 {
                slot.first_token_ms =
                    Some(slot.p.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            slot.tokens.push(next);
            if let Some(stream) = &slot.p.stream {
                let _ = stream.send(Token {
                    id: slot.p.req.id,
                    index,
                    token: next,
                });
            }
            if slot.tokens.len() >= slot.p.req.max_new {
                // finished: retire immediately — blocks go back to the
                // free list and the slot backfills next iteration (no
                // batch barrier)
                let s = slots[si].take().unwrap();
                cache.release_slot(si);
                active -= 1;
                let total_ms =
                    s.p.enqueued.elapsed().as_secs_f64() * 1e3;
                // stats land before the send (see `serve_one`)
                stats.lock().unwrap().record_latency(total_ms);
                let _ = s.p.tx.send(Completion {
                    id: s.p.req.id,
                    tokens: s.tokens,
                    queue_ms: s.queue_ms,
                    first_token_ms: s.first_token_ms.unwrap_or(total_ms),
                    total_ms,
                    prefill_tokens: s.p.req.prompt.len(),
                });
            } else {
                slot.next_feed = next;
            }
        }
    }
}
