//! Shared admission queue: the one synchronization point between
//! callers and the N shard engines.
//!
//! Built exclusively on the `util::sync` shim (the xtask
//! shim-confinement gate keeps raw `std::sync` lock types out of this
//! file), so the whole handoff protocol model-checks under loom — see
//! `loom_tests` at the bottom and `.github/workflows/analysis.yml`.
//!
//! ## Protocol
//!
//! One mutex guards the FIFO plus the stop flag; one condvar carries
//! "queue became non-empty" and "shutdown began".  Producers
//! ([`AdmissionQueue::push`], called from `Server::submit*`) append and
//! `notify_all`; waking *all* shards instead of one is deliberate —
//! `notify_one` could hand the wakeup to a shard whose scan then
//! declines the head for lack of blocks, losing the wakeup while a
//! shard with capacity sleeps.  Placement is pull-based work stealing:
//! whichever shard wins the lock scans the FIFO head under its own
//! capacity budget, so requests drain to whichever shard has free
//! slots/blocks first, and a head that must wait for one shard's
//! blocks can still be taken by an idler shard on its next wave.
//!
//! ## Invariants (the loom models pin these)
//!
//! * **Exactly-once dispatch**: a pushed request is popped by exactly
//!   one shard — the FIFO is only touched under the mutex, and a scan
//!   that pops a request owns it (there is no re-queue path).
//! * **Shutdown drains**: [`AdmissionQueue::poll`] reports `Stopped`
//!   only when the queue is empty, so requests enqueued before
//!   `shutdown` are always dispatched, never dropped.
//! * **No lost wakeup**: `stop` lives *inside* the mutex (not in an
//!   atomic beside it), so a shard cannot re-check the flag, decide to
//!   sleep, and miss a `shutdown` that landed in between — the old
//!   single-engine loop needed a 50 ms `wait_timeout` poll to paper
//!   over exactly that race; the sharded queue waits indefinitely.
//! * **Never blocks a working shard**: `poll(has_active = true, …)`
//!   returns without waiting, so a shard with sequences mid-decode
//!   checks for backfill and moves straight on to its engine step.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::Weak;
use std::time::{Duration, Instant};

use crate::serve::{Completion, Request, Token};
use crate::util::sync::{self, Condvar, Mutex, MutexGuard};

/// A submitted request parked in the admission queue: the request
/// itself plus the caller-side channel ends and liveness watch.
pub(crate) struct Pending {
    pub(crate) req: Request,
    pub(crate) enqueued: Instant,
    pub(crate) tx: Sender<Completion>,
    pub(crate) stream: Option<Sender<Token>>,
    /// liveness of the caller-side receivers (completion + optional
    /// stream): when every watch fails to upgrade, nobody can observe
    /// this request's results anymore
    pub(crate) watch: Vec<Weak<()>>,
}

impl Pending {
    pub(crate) fn abandoned(&self) -> bool {
        self.watch.iter().all(|w| w.upgrade().is_none())
    }
}

/// What one admission wave handed a shard.
pub(crate) enum Wave {
    /// Requests this shard's scan claimed (possibly empty: the shard
    /// had active sequences, or its capacity declined the FIFO head).
    Admitted(Vec<Pending>),
    /// Shutdown began and the queue is fully drained: exit the loop.
    Stopped,
}

struct State {
    items: VecDeque<Pending>,
    stop: bool,
    /// high-water mark of `items.len()`, updated at every push —
    /// surfaced as the `queue_peak` gauge on `EngineStats`
    peak: usize,
}

/// The shared FIFO + stop flag all shard engines pull from.
pub(crate) struct AdmissionQueue {
    state: Mutex<State>,
    cv: Condvar,
}

impl AdmissionQueue {
    pub(crate) fn new() -> AdmissionQueue {
        AdmissionQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                stop: false,
                peak: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Lock the queue state.  A poisoned lock is benign here — the
    /// state is a plain FIFO + flags with no invariant a panicking
    /// shard could half-apply — so recover the guard (same policy as
    /// the worker pool in `sparse::par`).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Append a request and wake every parked shard (see the module
    /// docs for why `notify_all`).
    pub(crate) fn push(&self, p: Pending) {
        let mut st = self.lock();
        st.items.push_back(p);
        st.peak = st.peak.max(st.items.len());
        drop(st);
        self.cv.notify_all();
    }

    pub(crate) fn len(&self) -> usize {
        self.lock().items.len()
    }

    /// Peak queue depth since start (the `queue_peak` gauge).
    pub(crate) fn peak(&self) -> usize {
        self.lock().peak
    }

    /// Begin shutdown: shards drain the remaining FIFO, then exit.
    pub(crate) fn shutdown(&self) {
        self.lock().stop = true;
        self.cv.notify_all();
    }

    /// One admission wave for a continuous-mode shard.  An idle shard
    /// (`has_active == false`) parks on the condvar until a request
    /// arrives or shutdown begins; a busy shard never waits.  Once
    /// awake, `scan` runs under the queue lock and claims whatever
    /// prefix of the FIFO the shard's capacity covers (popping an item
    /// transfers ownership — exactly-once dispatch).  `scan` must be
    /// deterministic sequential logic over the deque and the shard's
    /// own state: it runs with the lock held, so no kernel work and
    /// no other lock belongs inside it (lock order: the queue lock is
    /// a leaf).  The continuous engine *admits* inside its scan —
    /// reserving KV, attaching shared prefix blocks, and copying at
    /// most one block of K/V rows — which stays within the contract:
    /// bounded shard-local work against the shard's own pool, so the
    /// budget checked is exactly the budget consumed, with no window
    /// for a concurrent install to invalidate the plan.
    ///
    /// Liveness note: an idle shard's capacity always covers the FIFO
    /// head (an idle shard's KV pool is fully free, and `submit`
    /// rejects requests larger than a whole pool), so a non-empty
    /// queue with every shard idle cannot spin without progress.
    pub(crate) fn poll<F>(&self, has_active: bool, scan: F) -> Wave
    where
        F: FnOnce(&mut VecDeque<Pending>) -> Vec<Pending>,
    {
        let mut st = self.lock();
        while !has_active && st.items.is_empty() {
            if st.stop {
                return Wave::Stopped;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        Wave::Admitted(scan(&mut st.items))
    }

    /// Dequeue one batch for a sequential-mode shard: wait for the
    /// first request, then keep collecting up to `max` until
    /// `max_wait` expires.  Returns `None` once shutdown begins and
    /// the queue is drained; a shutdown with requests still queued
    /// skips the batch-fill wait and drains immediately.
    pub(crate) fn collect_batch(
        &self, max: usize, max_wait: Duration,
    ) -> Option<Vec<Pending>> {
        let mut st = self.lock();
        while st.items.is_empty() {
            if st.stop {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let deadline = Instant::now() + max_wait;
        while !st.stop && st.items.len() < max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timed_out) =
                sync::wait_timeout(&self.cv, st, deadline - now);
            st = guard;
            if timed_out {
                break;
            }
        }
        let take = st.items.len().min(max);
        Some(st.items.drain(..take).collect())
    }
}

/// Loom models of the admission handoff (run via `RUSTFLAGS="--cfg
/// loom" cargo test --release --lib loom_`, see analysis.yml).  The
/// shard stand-ins replay the protocol shape — park when idle, scan
/// under the lock, drain on shutdown — with synthetic capacity
/// closures in place of the real block-budget arithmetic, which is
/// deterministic sequential logic under the lock and adds nothing to
/// the interleaving space (the same reduction PR 7 used for the
/// worker pool's partition bodies).  Each model stays within loom's
/// default thread budget (main + at most 2 spawned shards).
#[cfg(all(test, loom))]
mod loom_tests {
    use std::sync::mpsc::channel;

    use super::*;
    use crate::model::sample::SamplingParams;
    use crate::util::sync::spawn_named;
    use std::sync::Arc;

    fn pending(id: u64) -> Pending {
        // the receiver is dropped immediately: the models never send
        // on the channel, they only track dispatch of the Pending
        let (tx, _rx) = channel();
        Pending {
            req: Request {
                id,
                prompt: vec![1],
                max_new: 1,
                params: SamplingParams::greedy(),
            },
            enqueued: Instant::now(),
            tx,
            stream: None,
            watch: Vec::new(),
        }
    }

    /// A shard stand-in: poll until `Stopped`, claiming at most
    /// `cap_per_wave` requests per wave (a fixed capacity budget, the
    /// shape of the real block/slot scan), recording claimed ids.
    fn run_shard(
        q: &AdmissionQueue, cap_per_wave: usize, got: &Mutex<Vec<u64>>,
    ) {
        loop {
            match q.poll(false, |items| {
                let take = items.len().min(cap_per_wave);
                items.drain(..take).collect()
            }) {
                Wave::Stopped => return,
                Wave::Admitted(v) => {
                    let mut g =
                        got.lock().unwrap_or_else(|e| e.into_inner());
                    g.extend(v.iter().map(|p| p.req.id));
                }
            }
        }
    }

    /// Two shards racing over a two-deep queue with capacity 1 per
    /// wave: every interleaving must dispatch both requests exactly
    /// once (no lost request, no double dispatch), regardless of
    /// which shard wins which wave.
    #[test]
    fn loom_two_shards_steal_exactly_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new());
            q.push(pending(0));
            q.push(pending(1));
            let got = Arc::new(Mutex::new(Vec::new()));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let q = q.clone();
                    let got = got.clone();
                    spawn_named("shard", move || run_shard(&q, 1, &got))
                })
                .collect();
            q.shutdown();
            for h in handles {
                h.join().unwrap();
            }
            let mut ids =
                got.lock().unwrap_or_else(|e| e.into_inner()).clone();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1], "lost or double-dispatched");
        });
    }

    /// Push racing a parked shard racing shutdown: the request must be
    /// dispatched exactly once whether the shard parks before the
    /// push, between push and shutdown, or only polls after both.
    #[test]
    fn loom_push_shutdown_race_delivers_exactly_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new());
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let h = spawn_named("shard", move || run_shard(&q2, 8, &g2));
            q.push(pending(7));
            q.shutdown();
            h.join().unwrap();
            let ids = got.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(*ids, vec![7], "shutdown lost the queued request");
        });
    }

    /// A wave that declines the head (capacity 0 — the Admit::Wait
    /// shape) must leave it in the FIFO for a later wave, not drop it:
    /// the shard's second wave claims it, shutdown only then lands.
    #[test]
    fn loom_declined_head_is_not_lost() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new());
            q.push(pending(3));
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let h = spawn_named("shard", move || {
                let mut first_wave = true;
                loop {
                    match q2.poll(false, |items| {
                        if first_wave {
                            first_wave = false;
                            Vec::new() // no capacity yet: leave the head
                        } else {
                            items.drain(..).collect()
                        }
                    }) {
                        Wave::Stopped => return,
                        Wave::Admitted(v) => {
                            let mut g = g2
                                .lock()
                                .unwrap_or_else(|e| e.into_inner());
                            g.extend(v.iter().map(|p| p.req.id));
                        }
                    }
                }
            });
            q.shutdown();
            h.join().unwrap();
            let ids = got.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(*ids, vec![3], "declined head was dropped");
        });
    }

    /// A shard with active sequences never parks: poll on an empty,
    /// un-stopped queue must return an empty wave immediately (the
    /// model completing at all proves it didn't block).
    #[test]
    fn loom_poll_with_active_never_blocks() {
        loom::model(|| {
            let q = AdmissionQueue::new();
            match q.poll(true, |items| {
                assert!(items.is_empty());
                Vec::new()
            }) {
                Wave::Admitted(v) => assert!(v.is_empty()),
                Wave::Stopped => {
                    panic!("stop reported without shutdown")
                }
            }
        });
    }
}
