//! Shared admission queue: the one synchronization point between
//! callers and the N shard engines.
//!
//! Built exclusively on the `util::sync` shim (the xtask
//! shim-confinement gate keeps raw `std::sync` lock types out of this
//! file), so the whole handoff protocol model-checks under loom — see
//! `loom_tests` at the bottom and `.github/workflows/analysis.yml`.
//!
//! ## Protocol
//!
//! One mutex guards the FIFO plus the stop flag; the `cv` condvar
//! carries "queue became non-empty" and "shutdown began", and a second
//! condvar (`cv_space`) carries "the queue shrank" to producers parked
//! in [`AdmissionQueue::push_wait`].  Producers (called from
//! `Server::submit*`) append and `notify_all`; waking *all* shards
//! instead of one is deliberate — `notify_one` could hand the wakeup
//! to a shard whose scan then declines the head for lack of blocks,
//! losing the wakeup while a shard with capacity sleeps.  Placement is
//! pull-based work stealing: whichever shard wins the lock scans the
//! FIFO head under its own capacity budget, so requests drain to
//! whichever shard has free slots/blocks first, and a head that must
//! wait for one shard's blocks can still be taken by an idler shard on
//! its next wave.
//!
//! ## Bounded admission (`max_queue`)
//!
//! The FIFO is capped at `cap` entries (0 = unbounded, the historical
//! behaviour).  [`AdmissionQueue::try_push`] refuses a full queue
//! immediately (`PushOutcome::Full`, counted under `queue_rejections`)
//! — the non-blocking shed path.  [`AdmissionQueue::push_wait`] parks
//! on `cv_space` until a scan pops or sheds an entry; with a
//! `max_wait` it gives up after that long (counted under `shed_busy`).
//! Every path that shrinks the FIFO (`poll`'s scan, `collect_batch`,
//! shutdown) notifies `cv_space`, so a parked producer cannot miss the
//! space it is waiting for — the bounded-queue loom models pin this.
//!
//! ## Invariants (the loom models pin these)
//!
//! * **Exactly-once dispatch**: a pushed request is popped by exactly
//!   one shard — the FIFO is only touched under the mutex, and a scan
//!   that pops a request owns it (there is no re-queue path).
//! * **Shutdown drains**: [`AdmissionQueue::poll`] reports `Stopped`
//!   only when the queue is empty, so requests enqueued before
//!   `shutdown` are always dispatched, never dropped.
//! * **No lost wakeup**: `stop` lives *inside* the mutex (not in an
//!   atomic beside it), so a shard cannot re-check the flag, decide to
//!   sleep, and miss a `shutdown` that landed in between — the old
//!   single-engine loop needed a 50 ms `wait_timeout` poll to paper
//!   over exactly that race; the sharded queue waits indefinitely.
//! * **Never blocks a working shard**: `poll(has_active = true, …)`
//!   returns without waiting, so a shard with sequences mid-decode
//!   checks for backfill and moves straight on to its engine step.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::Weak;
use std::time::{Duration, Instant};

use crate::serve::{Completion, Request, Token};
use crate::util::sync::{self, Condvar, Mutex, MutexGuard};

/// A submitted request parked in the admission queue: the request
/// itself plus the caller-side channel ends and liveness watch.
pub(crate) struct Pending {
    pub(crate) req: Request,
    pub(crate) enqueued: Instant,
    /// absolute completion deadline (`SubmitOptions::deadline`): the
    /// admission scan sheds a queued request once it passes, and the
    /// engine aborts an in-flight sequence at it
    pub(crate) deadline: Option<Instant>,
    pub(crate) tx: Sender<Completion>,
    pub(crate) stream: Option<Sender<Token>>,
    /// liveness of the caller-side receivers (completion + optional
    /// stream): when every watch fails to upgrade, nobody can observe
    /// this request's results anymore
    pub(crate) watch: Vec<Weak<()>>,
}

impl Pending {
    pub(crate) fn abandoned(&self) -> bool {
        self.watch.iter().all(|w| w.upgrade().is_none())
    }
}

/// What one admission wave handed a shard.
pub(crate) enum Wave {
    /// Requests this shard's scan claimed (possibly empty: the shard
    /// had active sequences, or its capacity declined the FIFO head).
    Admitted(Vec<Pending>),
    /// Shutdown began and the queue is fully drained: exit the loop.
    Stopped,
}

/// Result of a producer-side push against the bounded FIFO.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum PushOutcome {
    Pushed,
    /// the queue was at `max_queue` — immediately (`try_push`) or for
    /// the whole `max_wait` (`push_wait`)
    Full,
    /// shutdown began: no new requests
    Stopped,
}

struct State {
    items: VecDeque<Pending>,
    stop: bool,
    /// high-water mark of `items.len()`, updated at every push —
    /// surfaced as the `queue_peak` gauge on `EngineStats`
    peak: usize,
    /// non-blocking pushes refused at capacity (`queue_rejections`)
    rejections: u64,
    /// blocking pushes that timed out waiting for space (`shed_busy`)
    shed_busy: u64,
}

/// The shared FIFO + stop flag all shard engines pull from.
pub(crate) struct AdmissionQueue {
    state: Mutex<State>,
    /// "queue became non-empty / shutdown began" — shards park here
    cv: Condvar,
    /// "the queue shrank / shutdown began" — producers park here
    cv_space: Condvar,
    /// max queued entries; 0 = unbounded
    cap: usize,
}

impl AdmissionQueue {
    pub(crate) fn new(max_queue: usize) -> AdmissionQueue {
        AdmissionQueue {
            state: Mutex::new(State {
                items: VecDeque::new(),
                stop: false,
                peak: 0,
                rejections: 0,
                shed_busy: 0,
            }),
            cv: Condvar::new(),
            cv_space: Condvar::new(),
            cap: max_queue,
        }
    }

    fn full(&self, st: &State) -> bool {
        self.cap != 0 && st.items.len() >= self.cap
    }

    /// Lock the queue state.  A poisoned lock is benign here — the
    /// state is a plain FIFO + flags with no invariant a panicking
    /// shard could half-apply — so recover the guard (same policy as
    /// the worker pool in `sparse::par`).
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn push_locked(&self, mut st: MutexGuard<'_, State>, p: Pending) {
        st.items.push_back(p);
        st.peak = st.peak.max(st.items.len());
        drop(st);
        // wake every parked shard (see the module docs for why
        // `notify_all`)
        self.cv.notify_all();
    }

    /// Non-blocking push: refuse a full (or stopped) queue instead of
    /// waiting.  A refusal at capacity counts under `queue_rejections`.
    pub(crate) fn try_push(&self, p: Pending) -> PushOutcome {
        let mut st = self.lock();
        if st.stop {
            return PushOutcome::Stopped;
        }
        if self.full(&st) {
            st.rejections += 1;
            return PushOutcome::Full;
        }
        self.push_locked(st, p);
        PushOutcome::Pushed
    }

    /// Blocking push with backpressure: park on `cv_space` while the
    /// queue is at capacity.  `max_wait` bounds the wait (`None` waits
    /// until space or shutdown); giving up counts under `shed_busy`.
    pub(crate) fn push_wait(
        &self, p: Pending, max_wait: Option<Duration>,
    ) -> PushOutcome {
        let mut st = self.lock();
        // the deadline is computed lazily so the loom models (which
        // always pass `None`) never touch the clock
        let give_up = max_wait.map(|d| Instant::now() + d);
        while !st.stop && self.full(&st) {
            match give_up {
                None => {
                    st = self
                        .cv_space
                        .wait(st)
                        .unwrap_or_else(|e| e.into_inner());
                }
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        st.shed_busy += 1;
                        return PushOutcome::Full;
                    }
                    let (guard, _) =
                        sync::wait_timeout(&self.cv_space, st, dl - now);
                    st = guard;
                }
            }
        }
        if st.stop {
            return PushOutcome::Stopped;
        }
        self.push_locked(st, p);
        PushOutcome::Pushed
    }

    pub(crate) fn len(&self) -> usize {
        self.lock().items.len()
    }

    /// Peak queue depth since start (the `queue_peak` gauge).
    pub(crate) fn peak(&self) -> usize {
        self.lock().peak
    }

    /// Non-blocking pushes refused at capacity so far (the
    /// `queue_rejections` counter).
    pub(crate) fn rejections(&self) -> u64 {
        self.lock().rejections
    }

    /// Blocking pushes that timed out waiting for space so far (the
    /// `shed_busy` counter).
    pub(crate) fn shed_busy(&self) -> u64 {
        self.lock().shed_busy
    }

    /// Begin shutdown: shards drain the remaining FIFO, then exit;
    /// producers parked for space give up with `Stopped`.
    pub(crate) fn shutdown(&self) {
        self.lock().stop = true;
        self.cv.notify_all();
        self.cv_space.notify_all();
    }

    /// One admission wave for a continuous-mode shard.  An idle shard
    /// (`has_active == false`) parks on the condvar until a request
    /// arrives or shutdown begins; a busy shard never waits.  Once
    /// awake, `scan` runs under the queue lock and claims whatever
    /// prefix of the FIFO the shard's capacity covers (popping an item
    /// transfers ownership — exactly-once dispatch).  `scan` must be
    /// deterministic sequential logic over the deque and the shard's
    /// own state: it runs with the lock held, so no kernel work and
    /// no other lock belongs inside it (lock order: the queue lock is
    /// a leaf).  The continuous engine *admits* inside its scan —
    /// reserving KV, attaching shared prefix blocks, and copying at
    /// most one block of K/V rows — which stays within the contract:
    /// bounded shard-local work against the shard's own pool, so the
    /// budget checked is exactly the budget consumed, with no window
    /// for a concurrent install to invalidate the plan.
    ///
    /// Liveness note: an idle shard's capacity always covers the FIFO
    /// head (an idle shard's KV pool is fully free, and `submit`
    /// rejects requests larger than a whole pool), so a non-empty
    /// queue with every shard idle cannot spin without progress.
    pub(crate) fn poll<F>(&self, has_active: bool, scan: F) -> Wave
    where
        F: FnOnce(&mut VecDeque<Pending>) -> Vec<Pending>,
    {
        let mut st = self.lock();
        while !has_active && st.items.is_empty() {
            if st.stop {
                return Wave::Stopped;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let before = st.items.len();
        let taken = scan(&mut st.items);
        let shrank = st.items.len() < before;
        drop(st);
        if shrank {
            // anything the scan popped *or shed* opened queue space:
            // wake producers parked in `push_wait`
            self.cv_space.notify_all();
        }
        Wave::Admitted(taken)
    }

    /// Dequeue one batch for a sequential-mode shard: wait for the
    /// first request, then keep collecting up to `max` until
    /// `max_wait` expires.  Returns `None` once shutdown begins and
    /// the queue is drained; a shutdown with requests still queued
    /// skips the batch-fill wait and drains immediately.
    pub(crate) fn collect_batch(
        &self, max: usize, max_wait: Duration,
    ) -> Option<Vec<Pending>> {
        let mut st = self.lock();
        while st.items.is_empty() {
            if st.stop {
                return None;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        let deadline = Instant::now() + max_wait;
        while !st.stop && st.items.len() < max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, timed_out) =
                sync::wait_timeout(&self.cv, st, deadline - now);
            st = guard;
            if timed_out {
                break;
            }
        }
        let take = st.items.len().min(max);
        let batch = st.items.drain(..take).collect();
        drop(st);
        if take > 0 {
            self.cv_space.notify_all();
        }
        Some(batch)
    }
}

/// Loom models of the admission handoff (run via `RUSTFLAGS="--cfg
/// loom" cargo test --release --lib loom_`, see analysis.yml).  The
/// shard stand-ins replay the protocol shape — park when idle, scan
/// under the lock, drain on shutdown — with synthetic capacity
/// closures in place of the real block-budget arithmetic, which is
/// deterministic sequential logic under the lock and adds nothing to
/// the interleaving space (the same reduction PR 7 used for the
/// worker pool's partition bodies).  Each model stays within loom's
/// default thread budget (main + at most 2 spawned shards).
#[cfg(all(test, loom))]
mod loom_tests {
    use std::sync::mpsc::channel;

    use super::*;
    use crate::model::sample::SamplingParams;
    use crate::util::sync::spawn_named;
    use std::sync::Arc;

    fn pending(id: u64) -> Pending {
        // the receiver is dropped immediately: the models never send
        // on the channel, they only track dispatch of the Pending
        let (tx, _rx) = channel();
        Pending {
            req: Request {
                id,
                prompt: vec![1],
                max_new: 1,
                params: SamplingParams::greedy(),
            },
            enqueued: Instant::now(),
            deadline: None,
            tx,
            stream: None,
            watch: Vec::new(),
        }
    }

    /// Unbounded-queue push for the models that predate the cap.
    fn push(q: &AdmissionQueue, p: Pending) {
        assert_eq!(q.push_wait(p, None), PushOutcome::Pushed);
    }

    /// A shard stand-in: poll until `Stopped`, claiming at most
    /// `cap_per_wave` requests per wave (a fixed capacity budget, the
    /// shape of the real block/slot scan), recording claimed ids.
    fn run_shard(
        q: &AdmissionQueue, cap_per_wave: usize, got: &Mutex<Vec<u64>>,
    ) {
        loop {
            match q.poll(false, |items| {
                let take = items.len().min(cap_per_wave);
                items.drain(..take).collect()
            }) {
                Wave::Stopped => return,
                Wave::Admitted(v) => {
                    let mut g =
                        got.lock().unwrap_or_else(|e| e.into_inner());
                    g.extend(v.iter().map(|p| p.req.id));
                }
            }
        }
    }

    /// Two shards racing over a two-deep queue with capacity 1 per
    /// wave: every interleaving must dispatch both requests exactly
    /// once (no lost request, no double dispatch), regardless of
    /// which shard wins which wave.
    #[test]
    fn loom_two_shards_steal_exactly_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(0));
            push(&q, pending(0));
            push(&q, pending(1));
            let got = Arc::new(Mutex::new(Vec::new()));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let q = q.clone();
                    let got = got.clone();
                    spawn_named("shard", move || run_shard(&q, 1, &got))
                })
                .collect();
            q.shutdown();
            for h in handles {
                h.join().unwrap();
            }
            let mut ids =
                got.lock().unwrap_or_else(|e| e.into_inner()).clone();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1], "lost or double-dispatched");
        });
    }

    /// Push racing a parked shard racing shutdown: the request must be
    /// dispatched exactly once whether the shard parks before the
    /// push, between push and shutdown, or only polls after both.
    #[test]
    fn loom_push_shutdown_race_delivers_exactly_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(0));
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let h = spawn_named("shard", move || run_shard(&q2, 8, &g2));
            push(&q, pending(7));
            q.shutdown();
            h.join().unwrap();
            let ids = got.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(*ids, vec![7], "shutdown lost the queued request");
        });
    }

    /// A wave that declines the head (capacity 0 — the Admit::Wait
    /// shape) must leave it in the FIFO for a later wave, not drop it:
    /// the shard's second wave claims it, shutdown only then lands.
    #[test]
    fn loom_declined_head_is_not_lost() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(0));
            push(&q, pending(3));
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let h = spawn_named("shard", move || {
                let mut first_wave = true;
                loop {
                    match q2.poll(false, |items| {
                        if first_wave {
                            first_wave = false;
                            Vec::new() // no capacity yet: leave the head
                        } else {
                            items.drain(..).collect()
                        }
                    }) {
                        Wave::Stopped => return,
                        Wave::Admitted(v) => {
                            let mut g = g2
                                .lock()
                                .unwrap_or_else(|e| e.into_inner());
                            g.extend(v.iter().map(|p| p.req.id));
                        }
                    }
                }
            });
            q.shutdown();
            h.join().unwrap();
            let ids = got.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(*ids, vec![3], "declined head was dropped");
        });
    }

    /// A shard with active sequences never parks: poll on an empty,
    /// un-stopped queue must return an empty wave immediately (the
    /// model completing at all proves it didn't block).
    #[test]
    fn loom_poll_with_active_never_blocks() {
        loom::model(|| {
            let q = AdmissionQueue::new(0);
            match q.poll(true, |items| {
                assert!(items.is_empty());
                Vec::new()
            }) {
                Wave::Admitted(v) => assert!(v.is_empty()),
                Wave::Stopped => {
                    panic!("stop reported without shutdown")
                }
            }
        });
    }

    /// Bounded queue, producer blocked at capacity vs a popping shard:
    /// the space wakeup must never be lost.  cap = 1, item 0 fills the
    /// queue; a producer parks in `push_wait(item 1)` while a shard
    /// drains.  Every interleaving must dispatch *both* items — if a
    /// scan's pop failed to notify `cv_space`, the producer would park
    /// forever and loom would report the deadlock.
    #[test]
    fn loom_push_at_capacity_vs_pop_never_loses_wakeup() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(1));
            push(&q, pending(0)); // queue now at capacity
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let shard = spawn_named("shard", move || run_shard(&q2, 1, &g2));
            let q3 = q.clone();
            let producer = spawn_named("producer", move || {
                assert_eq!(
                    q3.push_wait(pending(1), None),
                    PushOutcome::Pushed,
                    "blocking push must wait for space, not give up"
                );
            });
            producer.join().unwrap();
            // only after item 1 is in: drain and stop the shard
            q.shutdown();
            shard.join().unwrap();
            let mut ids =
                got.lock().unwrap_or_else(|e| e.into_inner()).clone();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1], "an item or a wakeup was lost");
        });
    }

    /// Shutdown with a full queue: the queued item drains exactly
    /// once, and a producer parked for space gives up with `Stopped`
    /// instead of parking forever or sneaking its item in after stop.
    #[test]
    fn loom_shutdown_with_full_queue_drains_exactly_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(1));
            push(&q, pending(0)); // queue now at capacity
            let got = Arc::new(Mutex::new(Vec::new()));
            let (q2, g2) = (q.clone(), got.clone());
            let shard = spawn_named("shard", move || run_shard(&q2, 1, &g2));
            let q3 = q.clone();
            let outcome = Arc::new(Mutex::new(None));
            let o3 = outcome.clone();
            let producer = spawn_named("producer", move || {
                // races the shard's pop and the shutdown: space may
                // open before stop lands (Pushed) or not (Stopped) —
                // but a Pushed item must then be dispatched
                let r = q3.push_wait(pending(1), None);
                *o3.lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
            });
            q.shutdown();
            producer.join().unwrap();
            shard.join().unwrap();
            let outcome = outcome
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .unwrap();
            let mut ids =
                got.lock().unwrap_or_else(|e| e.into_inner()).clone();
            ids.sort_unstable();
            match outcome {
                PushOutcome::Pushed => {
                    assert_eq!(ids, vec![0, 1], "accepted item was lost")
                }
                PushOutcome::Stopped => {
                    assert_eq!(ids, vec![0], "queue drained != exactly once")
                }
                PushOutcome::Full => {
                    panic!("push_wait(None) can never report Full")
                }
            }
        });
    }

    /// Deadline-shed vs steal: one queued request, one shard whose
    /// scan *sheds* the head (the deadline-passed path: pop without
    /// dispatch) racing one that admits normally.  The request must
    /// land exactly once — shed or admitted, never both, never lost.
    #[test]
    fn loom_deadline_shed_vs_steal_dispatches_once() {
        loom::model(|| {
            let q = Arc::new(AdmissionQueue::new(0));
            push(&q, pending(5));
            let admitted = Arc::new(Mutex::new(Vec::new()));
            let shed = Arc::new(Mutex::new(Vec::new()));
            let (q2, a2) = (q.clone(), admitted.clone());
            let stealer = spawn_named("shard", move || run_shard(&q2, 1, &a2));
            let (q3, s3) = (q.clone(), shed.clone());
            let shedder = spawn_named("shard", move || {
                loop {
                    match q3.poll(false, |items| {
                        // the deadline sweep: drop the head from the
                        // FIFO, recording it as shed — it is never
                        // part of the returned (admitted) wave
                        if let Some(p) = items.pop_front() {
                            let mut g = s3
                                .lock()
                                .unwrap_or_else(|e| e.into_inner());
                            g.push(p.req.id);
                        }
                        Vec::new()
                    }) {
                        Wave::Stopped => return,
                        Wave::Admitted(v) => assert!(v.is_empty()),
                    }
                }
            });
            q.shutdown();
            stealer.join().unwrap();
            shedder.join().unwrap();
            let a = admitted.lock().unwrap_or_else(|e| e.into_inner());
            let s = shed.lock().unwrap_or_else(|e| e.into_inner());
            assert_eq!(
                a.len() + s.len(),
                1,
                "request must be shed or admitted exactly once \
                 (admitted {a:?}, shed {s:?})"
            );
            let seen = a.first().or(s.first()).copied();
            assert_eq!(seen, Some(5));
        });
    }
}
