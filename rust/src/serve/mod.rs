//! Serving layer: continuous-batching inference engine over the rust
//! model (the vllm-shaped L3 component).
//!
//! Requests enter a shared admission queue; each *shard* engine thread
//! owns the model (shared read-only) plus a *paged* KV pool
//! (`PagedKvCache`): physical KV storage is a per-shard array of
//! fixed-size blocks (`kv_block_size` positions each, `kv_blocks`
//! total), and each admitted sequence maps its logical positions onto
//! physical blocks through a per-slot block table that grows on
//! demand.  Long and short requests therefore share physical KV
//! memory instead of each stranding a fixed `max_context` region, and
//! an oversized prompt needs no special path — any request that fits
//! the pool is batched like every other.
//!
//! Every engine iteration a shard (1) admits queued requests in FIFO
//! order while a sequence slot is free AND its pool's block budget
//! covers the request's worst case (`kv_positions_needed`) — under
//! memory pressure admission *waits* for retiring sequences to return
//! blocks rather than overcommitting — (2) retires sequences whose
//! caller dropped every receiver (their blocks return to the free list
//! instead of decoding into a dead channel), (3) advances all active
//! slots with `Model::prefill_decode_step_into`: a prefilling slot
//! feeds up to `prefill_chunk` prompt tokens (one KV block by default)
//! while a decoding slot feeds its last sampled token, so a mixed
//! batch presents a `(sum of span lengths, d)` activation matrix to
//! the FFN backends (the TwELL pipeline runs batched exactly where it
//! pays most: long-prompt prefill) and writes whole blocks of K/V rows
//! per step — every buffer on that path lives in the shard's one
//! `DecodeScratch` (no per-step heap allocation), the kernels run on
//! the persistent worker pool, and skinny decode batches dispatch
//! column-parallel instead of collapsing onto one core — and (4)
//! retires finished sequences immediately, returning
//! their blocks to the free list and backfilling their slots from the
//! queue on the next iteration (no batch barrier).  Prefill is
//! interleaved with decode chunk-by-chunk (Orca-style iteration-level
//! scheduling), so a length-L prompt completes prefill in
//! `ceil(L / prefill_chunk)` iterations without starving decode, and
//! chunked prefill stays bit-exact with the token-by-token path (the
//! parity tests are the contract).  Each `Completion` reports
//! `first_token_ms` (TTFT), which is what chunking improves.
//!
//! Degenerate requests (empty prompt, or `max_new == 0`) are answered
//! with an empty `Completion`: an empty prompt produces no logits, so
//! there is nothing to sample.  A request whose worst case exceeds an
//! *entire* shard pool could never be admitted, so `submit` rejects it
//! up front with an actionable error instead of queueing it forever.
//!
//! Per-token streaming: `submit_streaming` returns an `Rx<Token>`
//! that yields each generated token as it is chosen, alongside the
//! final `Completion`.
//!
//! Token selection is per-request (`model::sample`): every request
//! carries `SamplingParams { temperature, top_k, top_p, seed }` and
//! owns a private seeded RNG, so its completion is reproducible no
//! matter how the scheduler interleaves it with other traffic.
//! `submit`/`submit_streaming` default to greedy; the `_sampled`
//! variants take explicit params (validated at the submit boundary).
//! One uniform draw is consumed per sampled token — and none when
//! greedy — so the stream depends only on the logits sequence, which
//! both scheduler paths produce bit-exactly (the parity tests are the
//! contract).  `temperature == 0` short-circuits to argmax, keeping
//! greedy requests bit-exact with `Model::generate`.
//!
//! The pre-refactor collect-then-serialize path is kept behind
//! `ServeMode::Sequential` as the parity baseline.  Both paths share
//! the same sampler, so a given `(seed, prompt)` yields the same
//! tokens on either.
//!
//! # Sharded architecture (`ServePolicy::shards`)
//!
//! The serve layer is three submodules behind this facade:
//!
//! * `serve/admission.rs` — the shared FIFO admission queue + stop
//!   flag every shard pulls from, built on the `util::sync` shim so
//!   its handoff protocol model-checks under loom
//!   (`admission::loom_tests`).
//! * `serve/engine.rs` — the per-shard continuous/sequential loops.
//!   Each shard owns a full `PagedKvCache` (`policy.kv_blocks`
//!   blocks), `policy.slots` sequence slots and one `DecodeScratch`;
//!   total serving capacity is `shards ×` each of those.
//! * `serve/stats.rs` — [`EngineStats`] + cross-shard merging
//!   (counters sum, gauges max, histograms add element-wise).
//!
//! **Shard topology.** `Server::start` spawns `policy.shards` engine
//! threads (through `util::sync::spawn_named`, named
//! `repro-serve-<i>`), each running `policy.mode`'s loop against the
//! one shared queue.  The model sits behind an `Arc`, read-only;
//! every mutable structure (cache, slots, scratch, stats) is
//! per-shard.  Kernels from all shards serialize on the
//! process-global worker pool (`sparse::par` has one job slot), so
//! callers size the pool with `par::threads_per_shard(total, shards)`
//! — the `--threads` budget is a *total* split across shards.
//!
//! **Placement policy.** Pull-based work stealing, not assignment: an
//! idle shard parks on the queue condvar; a push wakes all shards and
//! whichever wins the lock admits the FIFO head under its own
//! slot/block budget.  A head too big for a busy shard's free blocks
//! stays queued (FIFO order is never reordered) and the next shard
//! with capacity takes it.  There is no shard affinity to tune —
//! per-request seeded samplers make every completion independent of
//! placement, which the cross-shard parity tests pin bit-exactly at
//! shards {1, 2, 4} on both FFN backends.
//!
//! **Lock order.** The queue mutex, the per-shard stats mutexes and
//! the per-shard roster mutexes are never held across a kernel call.
//! Exactly one nesting exists: the admission scan (which runs under
//! the queue lock, doing pure slot/block-budget arithmetic) takes the
//! shard's *roster* lock as a leaf to record each popped request for
//! the panic supervisor (`serve/engine.rs` docs).  Queue → roster is
//! the only two-lock chain in the layer; stats stays a leaf.
//!
//! **Admission protocol invariants** (loom-modeled): every pushed
//! request is dispatched to exactly one shard; shutdown drains the
//! queue before any shard exits; no lost wakeups (`stop` lives inside
//! the queue mutex, so there is no check-then-sleep race); a shard
//! with active sequences never blocks on an empty queue; a producer
//! blocked on a full bounded queue always observes the next pop or
//! shutdown; a deadline-shed and a steal of the same request cannot
//! both happen.
//!
//! # Overload safety (the QoS layer)
//!
//! Under overload an unbounded FIFO converts excess arrivals into
//! unbounded queueing delay: every request is eventually answered,
//! uselessly late, and memory grows without bound.  The serve layer
//! instead degrades deliberately, in four places:
//!
//! 1. **Bounded admission** (`ServePolicy::max_queue`): the queue
//!    caps pending requests.  [`Server::try_submit_sampled`] returns
//!    [`SubmitError::Busy`] instead of queueing when full — callers
//!    that can retry or divert should use it — while the blocking
//!    `submit*` family waits for space (backpressure), bounded by
//!    `SubmitOptions::max_queue_wait` when one is given.  Rejections
//!    land in `queue_rejections`; bounded waits that expire land in
//!    `shed_busy`.
//! 2. **Queued-request shedding**: every admission scan sweeps the
//!    whole queue and sheds requests whose `SubmitOptions::deadline`
//!    has passed — or provably cannot be met (the engine keeps an
//!    EWMA of per-position service time) — completing them
//!    immediately with [`FinishReason::DeadlineExceeded`] and zero KV
//!    spend (`shed_deadline`).  Abandoned requests are dropped from
//!    any queue position the same way (`abandoned`).
//! 3. **In-flight deadline aborts**: a decoding sequence whose
//!    deadline passes is retired at the next iteration — partial
//!    tokens delivered, KV blocks freed (`deadline_aborts`).
//! 4. **Shard panic isolation**: each shard loop runs under a
//!    supervisor (`engine::run_shard`) that converts a panic into
//!    [`FinishReason::ShardFailed`] completions for that shard's
//!    in-flight requests plus a shard restart with a fresh KV pool
//!    (`shard_restarts`), leaving the other shards serving
//!    throughout.
//!
//! Every completion carries a [`FinishReason`], so a caller can tell
//! a full answer (`Length`) from a shed, abort or failure without
//! inspecting token counts.  The deterministic fault-injection sites
//! behind all of this live in `util::failpoint` (`fail_point!`), and
//! the chaos tests drive them; `scripts/check_bench.py` gates the
//! `section=overload` rows of the serving bench, which sweep shed
//! on/off under the same open-loop overload.

mod admission;
mod engine;
mod stats;

pub use stats::{EngineStats, ServeMetrics, LATENCY_BUCKETS};

use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::kv::kv_positions_needed;
use crate::model::sample::SamplingParams;
use crate::model::Model;

use admission::{AdmissionQueue, Pending, PushOutcome};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request token selection (greedy when
    /// `SamplingParams::greedy()`); the seed makes the completion
    /// reproducible across scheduler paths.
    pub params: SamplingParams,
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_ms: f64,
    /// Time from enqueue to the *first generated token* (TTFT) — the
    /// latency prefill chunking improves.  Equals `total_ms` for empty
    /// completions, which never sample a token.
    pub first_token_ms: f64,
    pub total_ms: f64,
    pub prefill_tokens: usize,
    /// Why generation stopped — the only way to tell a full answer
    /// from a shed, an abort, or a shard failure (a deadline abort
    /// still delivers the tokens sampled before it).
    pub finish: FinishReason,
}

/// Why a `Completion` is final (see the module's overload-safety
/// section for the shedding taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new` tokens — the normal outcome (degenerate
    /// requests hit their zero-token limit immediately).
    Length,
    /// Reserved for stop-token termination: no tokenizer-level stop
    /// sequence exists in the testbed yet, so nothing emits this.
    Stop,
    /// Every receiver was dropped; delivery is best-effort (normally
    /// nobody is left to observe this value).
    Abandoned,
    /// The request's `SubmitOptions::deadline` passed — shed while
    /// queued (no tokens) or aborted mid-decode (partial tokens).
    DeadlineExceeded,
    /// The shard serving this request panicked; the supervisor failed
    /// the request while restarting the shard.  Safe to resubmit.
    ShardFailed,
}

/// One streamed token, sent the moment the engine samples it.
#[derive(Clone, Copy, Debug)]
pub struct Token {
    pub id: u64,
    /// 0-based index within the generated tokens
    pub index: usize,
    pub token: u32,
}

/// Per-request quality-of-service knobs (see the module's
/// overload-safety section).  `Default` is fully permissive: no
/// deadline, wait for queue space forever.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOptions {
    /// Absolute deadline.  While queued, a request whose deadline has
    /// passed (or provably cannot be met) is shed; once decoding, it
    /// is aborted at the next engine iteration with its partial
    /// tokens.  Either way the completion says `DeadlineExceeded`.
    pub deadline: Option<Instant>,
    /// How long a *blocking* submit may wait for queue space when the
    /// bounded queue is full (`None` = forever).  Ignored by
    /// `try_submit_sampled`, which never waits.
    pub max_queue_wait: Option<Duration>,
}

/// Why a submit was refused at the boundary (distinct from a
/// completion-level failure: a refused request was never queued).
#[derive(Debug)]
pub enum SubmitError {
    /// The admission queue is at `max_queue` (and, for a blocking
    /// submit, stayed full for all of `max_queue_wait`).  Transient:
    /// retry later, shed, or divert to another server.
    Busy,
    /// The server is shutting down and accepts no new requests.
    ShuttingDown,
    /// The request can never be served (bad sampling params, or a
    /// worst-case KV footprint beyond the whole pool).
    Invalid(anyhow::Error),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => {
                write!(f, "admission queue full (max_queue); try later")
            }
            SubmitError::ShuttingDown => {
                write!(f, "server is shutting down")
            }
            SubmitError::Invalid(e) => write!(f, "invalid request: {e}"),
        }
    }
}

impl std::error::Error for SubmitError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SubmitError::Invalid(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

/// Receiver handed out by `submit`/`submit_streaming`: derefs to the
/// underlying `mpsc::Receiver` (so `recv`/`recv_timeout`/`iter` work
/// unchanged) and additionally carries a liveness token the engine
/// watches.  Dropping an `Rx` is how a caller abandons a request —
/// once every receiver is gone the scheduler retires the slot early
/// and returns its KV blocks, instead of decoding to `max_new` into a
/// dead channel.
pub struct Rx<T> {
    rx: Receiver<T>,
    _alive: Arc<()>,
}

impl<T> Deref for Rx<T> {
    type Target = Receiver<T>;
    fn deref(&self) -> &Receiver<T> {
        &self.rx
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Legacy collect-then-serialize loop (kept for parity testing).
    Sequential,
    /// Slot-based continuous batching (the default).
    Continuous,
}

/// Scheduler tunables (`repro serve` and the serving benches sweep
/// these).
#[derive(Clone, Copy, Debug)]
pub struct ServePolicy {
    /// KV slot pool size *per shard*: max concurrently decoding
    /// sequences (continuous) or max collected batch (sequential).
    pub slots: usize,
    /// Sequential mode: how long to wait for the batch to fill.
    pub max_wait: Duration,
    /// Positions per physical KV block (paging granularity).
    pub kv_block_size: usize,
    /// Physical KV blocks *per shard*, shared by that shard's slots;
    /// a shard's admission budget is `kv_blocks * kv_block_size`
    /// positions pool-wide, not per slot.
    pub kv_blocks: usize,
    /// Max prompt tokens fed per prefilling slot per engine iteration
    /// (continuous mode; clamped to >= 1).  One KV block per step —
    /// the default — is the sweet spot: block-aligned chunks keep the
    /// paged grow path trivial, and a length-L prompt finishes prefill
    /// in `ceil(L / prefill_chunk)` iterations.  1 reproduces the old
    /// token-by-token prefill.
    pub prefill_chunk: usize,
    /// Union-density threshold for batch-contextual FFN routing on the
    /// TwELL backend (see `sparse::route`): a pure-decode step whose
    /// batch-union of active FFN columns covers at most this fraction
    /// of `d_ff` runs the routed union-gathered kernel; denser steps
    /// fall back to the fused row path.  `0.0` disables routing
    /// entirely.  Ignored by the dense backend.
    pub route_density: f32,
    /// Engine shards behind the shared admission queue (clamped to
    /// >= 1).  Each shard owns its own full `slots`/`kv_blocks`
    /// capacity and one engine thread; see the module docs for the
    /// topology and placement policy.
    pub shards: usize,
    /// Copy-on-write prefix caching over the paged KV pool
    /// (continuous mode): admissions whose prompt prefix matches
    /// blocks an earlier sequence wrote attach those blocks by
    /// refcount instead of recomputing them, collapsing TTFT for hot
    /// system prompts.  Decoded streams are bit-identical either way
    /// (same kernels, same accumulation order — only block placement
    /// changes), so this defaults to on; turn it off to pin the
    /// historical allocator behaviour.
    pub prefix_cache: bool,
    /// Bound on queued (admitted-but-not-started) requests across the
    /// whole server; `0` = unbounded (the historical behaviour).
    /// When full, `try_submit_sampled` returns `SubmitError::Busy`
    /// and the blocking `submit*` family waits for space.
    pub max_queue: usize,
    pub mode: ServeMode,
}

impl Default for ServePolicy {
    fn default() -> Self {
        ServePolicy {
            slots: 8,
            max_wait: Duration::from_millis(5),
            kv_block_size: 16,
            kv_blocks: 256,
            prefill_chunk: 16,
            route_density: crate::sparse::route::DEFAULT_ROUTE_DENSITY,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        }
    }
}

pub struct Server {
    queue: Arc<AdmissionQueue>,
    next_id: AtomicU64,
    workers: Vec<crate::util::sync::JoinHandle<()>>,
    shard_stats: Vec<Arc<Mutex<EngineStats>>>,
    pub policy: ServePolicy,
}

impl Server {
    /// Spawn `policy.shards` engine threads sharing the model and one
    /// admission queue.
    pub fn start(model: Model, policy: ServePolicy) -> Server {
        assert!(policy.slots > 0, "need at least one slot");
        let shards = policy.shards.max(1);
        let queue = Arc::new(AdmissionQueue::new(policy.max_queue));
        let model = Arc::new(model);
        let mut workers = Vec::with_capacity(shards);
        let mut shard_stats = Vec::with_capacity(shards);
        for i in 0..shards {
            let stats = Arc::new(Mutex::new(EngineStats::default()));
            let (m, q, st) = (model.clone(), queue.clone(), stats.clone());
            // each shard thread runs under the panic supervisor: a
            // panicking loop fails its in-flight requests and restarts
            // with a fresh KV pool instead of dying silently
            workers.push(crate::util::sync::spawn_named(
                &format!("repro-serve-{i}"),
                move || engine::run_shard(m, q, policy, st),
            ));
            shard_stats.push(stats);
        }
        Server {
            queue,
            next_id: AtomicU64::new(0),
            workers,
            shard_stats,
            policy,
        }
    }

    /// Enqueue a greedy request; returns (id, completion receiver).
    /// Errors if the request's worst-case KV footprint exceeds a whole
    /// shard pool (it could never be admitted).  Blocks for queue
    /// space when `max_queue` is set; `submit_opts` bounds that wait
    /// and `try_submit_sampled` refuses to wait at all.
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize)
        -> Result<(u64, Rx<Completion>)> {
        self.submit_sampled(prompt, max_new, SamplingParams::greedy())
    }

    /// Enqueue a request with explicit per-request sampling params
    /// (temperature / top-k / top-p / seed).  Params are validated
    /// here, at the submit boundary, so a bad request fails with an
    /// actionable error instead of a worker panic.
    pub fn submit_sampled(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
    ) -> Result<(u64, Rx<Completion>)> {
        let (id, _, rx) = self
            .enqueue(prompt, max_new, params, false,
                     SubmitOptions::default(), true)
            .map_err(anyhow::Error::new)?;
        Ok((id, rx))
    }

    /// Enqueue a greedy request with per-token streaming; returns
    /// (id, token receiver, completion receiver).
    pub fn submit_streaming(&self, prompt: Vec<u32>, max_new: usize)
        -> Result<(u64, Rx<Token>, Rx<Completion>)> {
        self.submit_streaming_sampled(
            prompt, max_new, SamplingParams::greedy(),
        )
    }

    /// Streaming variant of `submit_sampled`.
    pub fn submit_streaming_sampled(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
    ) -> Result<(u64, Rx<Token>, Rx<Completion>)> {
        let (id, stream_rx, rx) = self
            .enqueue(prompt, max_new, params, true,
                     SubmitOptions::default(), true)
            .map_err(anyhow::Error::new)?;
        Ok((id, stream_rx.unwrap(), rx))
    }

    /// QoS-aware blocking submit: carries a deadline and a bound on
    /// how long to wait for queue space (see [`SubmitOptions`]).
    /// Returns [`SubmitError::Busy`] when the wait budget expires
    /// with the queue still full.
    pub fn submit_opts(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
        opts: SubmitOptions,
    ) -> std::result::Result<(u64, Rx<Completion>), SubmitError> {
        let (id, _, rx) =
            self.enqueue(prompt, max_new, params, false, opts, true)?;
        Ok((id, rx))
    }

    /// Streaming variant of [`Server::submit_opts`].
    pub fn submit_streaming_opts(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
        opts: SubmitOptions,
    ) -> std::result::Result<(u64, Rx<Token>, Rx<Completion>), SubmitError>
    {
        let (id, stream_rx, rx) =
            self.enqueue(prompt, max_new, params, true, opts, true)?;
        Ok((id, stream_rx.unwrap(), rx))
    }

    /// Non-blocking submit: if the bounded queue is full this returns
    /// [`SubmitError::Busy`] *immediately* — it never waits, so an
    /// overloaded server sheds at the boundary instead of stacking
    /// callers.  Rejections count under `queue_rejections`.
    pub fn try_submit_sampled(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
        opts: SubmitOptions,
    ) -> std::result::Result<(u64, Rx<Completion>), SubmitError> {
        let (id, _, rx) =
            self.enqueue(prompt, max_new, params, false, opts, false)?;
        Ok((id, rx))
    }

    fn enqueue(
        &self, prompt: Vec<u32>, max_new: usize, params: SamplingParams,
        stream: bool, opts: SubmitOptions, block: bool,
    ) -> std::result::Result<
        (u64, Option<Rx<Token>>, Rx<Completion>),
        SubmitError,
    > {
        params.validate().map_err(SubmitError::Invalid)?;
        // reject impossible requests up front, with a message the
        // caller can act on — once queued they could only wait forever.
        // Degenerate requests (empty prompt / max_new == 0) are exempt:
        // they are answered with an empty completion using no KV.
        // The sequential path sizes its cache per request, no limit.
        // Every shard owns a full pool, so the bound is per shard.
        if self.policy.mode == ServeMode::Continuous
            && !prompt.is_empty()
            && max_new > 0
        {
            let need = kv_positions_needed(prompt.len(), max_new);
            let pool = self.policy.kv_blocks * self.policy.kv_block_size;
            if need > pool {
                return Err(SubmitError::Invalid(anyhow::anyhow!(
                    "request needs {need} KV positions but the pool \
                     holds {pool} ({} blocks x {} positions); raise \
                     --kv-blocks or lower max_new",
                    self.policy.kv_blocks,
                    self.policy.kv_block_size
                )));
            }
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let rx = Rx { rx, _alive: Arc::new(()) };
        // a zero-token request has a fully determined (empty) answer:
        // complete it here, at the submit boundary, instead of making
        // it ride the queue to an engine that would do the same — it
        // can never be shed, never go Busy, and never touch stats
        if max_new == 0 {
            let prefill_tokens = prompt.len();
            let _ = tx.send(Completion {
                id,
                tokens: Vec::new(),
                queue_ms: 0.0,
                first_token_ms: 0.0,
                total_ms: 0.0,
                prefill_tokens,
                finish: FinishReason::Length,
            });
            let stream_rx = stream.then(|| {
                // the paired sender drops right here: the stream ends
                // immediately, with zero tokens, matching the completion
                let (_, b) = channel();
                Rx { rx: b, _alive: Arc::new(()) }
            });
            return Ok((id, stream_rx, rx));
        }
        let mut watch = vec![Arc::downgrade(&rx._alive)];
        let (stream_tx, stream_rx) = if stream {
            let (a, b) = channel();
            let b = Rx { rx: b, _alive: Arc::new(()) };
            watch.push(Arc::downgrade(&b._alive));
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        let pending = Pending {
            req: Request { id, prompt, max_new, params },
            enqueued: Instant::now(),
            deadline: opts.deadline,
            tx,
            stream: stream_tx,
            watch,
        };
        let outcome = if block {
            self.queue.push_wait(pending, opts.max_queue_wait)
        } else {
            self.queue.try_push(pending)
        };
        match outcome {
            PushOutcome::Pushed => Ok((id, stream_rx, rx)),
            PushOutcome::Full => Err(SubmitError::Busy),
            PushOutcome::Stopped => Err(SubmitError::ShuttingDown),
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Merged snapshot of the engine counters across every shard:
    /// counters sum, gauges (`max_active`, `queue_peak`) take the
    /// max, the latency histogram adds element-wise.
    pub fn stats(&self) -> EngineStats {
        EngineStats::merged(&self.shard_stats())
    }

    /// Per-shard snapshots of the engine counters, each stamped with
    /// the queue-scope values (`queue_peak`, `queue_rejections`,
    /// `shed_busy`) — the queue belongs to no single shard, so every
    /// snapshot carries the same values and the merge's max preserves
    /// them.  Snapshot locks recover poison: a panicking shard leaves
    /// `Copy` counters at worst one event stale, never corrupt.
    pub fn shard_stats(&self) -> Vec<EngineStats> {
        let peak = self.queue.peak();
        let rejections = self.queue.rejections();
        let shed_busy = self.queue.shed_busy();
        self.shard_stats
            .iter()
            .map(|s| {
                let mut st =
                    *s.lock().unwrap_or_else(|e| e.into_inner());
                st.queue_peak = st.queue_peak.max(peak);
                st.queue_rejections = st.queue_rejections.max(rejections);
                st.shed_busy = st.shed_busy.max(shed_busy);
                st
            })
            .collect()
    }

    pub fn shutdown(mut self) {
        self.queue.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Re-exported for tests/benches: deterministic result check.
pub fn greedy_reference(model: &Model, prompt: &[u32], max_new: usize)
    -> Result<Vec<u32>> {
    Ok(model.generate(prompt, max_new))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;
    use crate::model::FfnBackend;
    use crate::util::prop::{check, Gen};

    fn policy(slots: usize, mode: ServeMode) -> ServePolicy {
        ServePolicy {
            slots,
            max_wait: Duration::from_millis(2),
            kv_block_size: 8,
            kv_blocks: 64,
            prefill_chunk: 8,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode,
        }
    }

    #[test]
    fn server_round_trip_matches_direct_generate() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[1, 2, 3], 4);
        let server = Server::start(model, ServePolicy::default());
        let (_, rx) = server.submit(vec![1, 2, 3], 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        assert_eq!(c.prefill_tokens, 3);
        assert_eq!(c.finish, FinishReason::Length);
        server.shutdown();
    }

    #[test]
    fn queue_ms_never_exceeds_total_ms() {
        // both scheduler modes: queue time is measured once at dequeue,
        // so it must be non-negative and bounded by the total latency
        for mode in [ServeMode::Sequential, ServeMode::Continuous] {
            let model = toy_model(FfnBackend::Dense);
            let server = Server::start(model, policy(2, mode));
            let rxs: Vec<_> = (0..6u32)
                .map(|i| server.submit(vec![i % 32, 3], 4).unwrap().1)
                .collect();
            for rx in rxs {
                let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                assert!(c.queue_ms >= 0.0, "{mode:?}: {}", c.queue_ms);
                assert!(c.queue_ms <= c.total_ms,
                        "{mode:?}: queue {} > total {}",
                        c.queue_ms, c.total_ms);
            }
            server.shutdown();
        }
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(4, ServeMode::Continuous));
        let mut rxs = Vec::new();
        for i in 0..20u32 {
            let (id, rx) =
                server.submit(vec![i % 32, (i + 1) % 32], 3).unwrap();
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.id, id);
            assert_eq!(c.tokens.len(), 3);
        }
        assert_eq!(server.queue_len(), 0);
        server.shutdown();
    }

    /// The headline parity guarantee: N concurrent ragged-length
    /// requests through the continuous engine produce token-for-token
    /// what sequential `generate` produces — for both FFN backends.
    fn continuous_parity(backend: FfnBackend) {
        let reference_model = toy_model(backend);
        let prompts: Vec<Vec<u32>> = vec![
            vec![1, 2, 3, 4, 5, 6, 7],
            vec![9],
            vec![30, 30, 2],
            vec![4, 0, 11, 19, 23],
            vec![8, 8],
        ];
        let max_news = [6usize, 2, 9, 1, 4];
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .zip(max_news)
            .map(|(p, n)| reference_model.generate(p, n))
            .collect();
        // slots < requests forces mid-flight backfill as well
        let server =
            Server::start(reference_model, policy(2, ServeMode::Continuous));
        let rxs: Vec<_> = prompts
            .iter()
            .zip(max_news)
            .map(|(p, n)| server.submit(p.clone(), n).unwrap().1)
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp, "served != generate ({backend:?})");
        }
        server.shutdown();
    }

    #[test]
    fn continuous_parity_dense() {
        continuous_parity(FfnBackend::Dense);
    }

    #[test]
    fn continuous_parity_twell() {
        continuous_parity(FfnBackend::Twell);
    }

    /// The sharding acceptance criterion: one mixed workload (sampled
    /// + greedy, ragged lengths) must produce bit-identical token
    /// streams at shards {1, 2, 4} — placement cannot perturb any
    /// request because each carries its own seeded sampler and every
    /// shard runs the same bit-exact kernels.  The greedy half is
    /// additionally pinned to `generate`, so all shard counts are
    /// anchored to the same external reference, not just each other.
    fn cross_shard_parity(backend: FfnBackend) {
        let reference_model = toy_model(backend);
        let prompts: Vec<Vec<u32>> = vec![
            vec![1, 2, 3, 4, 5, 6, 7],
            vec![9],
            vec![30, 30, 2],
            vec![4, 0, 11, 19, 23],
            vec![8, 8],
            vec![17, 3, 5, 21],
        ];
        let max_news = [6usize, 2, 9, 1, 4, 5];
        let greedy_expected: Vec<Vec<u32>> = prompts
            .iter()
            .zip(max_news)
            .map(|(p, n)| reference_model.generate(p, n))
            .collect();
        let run = |shards: usize| -> (Vec<Vec<u32>>, Vec<Vec<u32>>) {
            // slots=2 per shard: at 1 shard the queue backs up, at 4
            // shards requests spread out — maximally different
            // placements for the same workload
            let server = Server::start(toy_model(backend), ServePolicy {
                shards,
                ..policy(2, ServeMode::Continuous)
            });
            let sampled_rxs: Vec<_> = prompts
                .iter()
                .zip(max_news)
                .enumerate()
                .map(|(i, (p, n))| {
                    server
                        .submit_sampled(
                            p.clone(), n, sampled_params(100 + i as u64),
                        )
                        .unwrap()
                        .1
                })
                .collect();
            let greedy_rxs: Vec<_> = prompts
                .iter()
                .zip(max_news)
                .map(|(p, n)| server.submit(p.clone(), n).unwrap().1)
                .collect();
            let recv = |rxs: Vec<Rx<Completion>>| -> Vec<Vec<u32>> {
                rxs.into_iter()
                    .map(|rx| {
                        rx.recv_timeout(Duration::from_secs(60))
                            .unwrap()
                            .tokens
                    })
                    .collect()
            };
            let out = (recv(sampled_rxs), recv(greedy_rxs));
            server.shutdown();
            out
        };
        let golden = run(1);
        assert_eq!(golden.1, greedy_expected,
                   "single shard != generate ({backend:?})");
        for shards in [2usize, 4] {
            let got = run(shards);
            assert_eq!(got.0, golden.0,
                       "sampled streams diverged at {shards} shards \
                        ({backend:?})");
            assert_eq!(got.1, greedy_expected,
                       "greedy streams diverged at {shards} shards \
                        ({backend:?})");
        }
    }

    #[test]
    fn cross_shard_parity_dense() {
        cross_shard_parity(FfnBackend::Dense);
    }

    #[test]
    fn cross_shard_parity_twell() {
        cross_shard_parity(FfnBackend::Twell);
    }

    #[test]
    fn sharded_stats_merge_equals_sum_of_shards() {
        // the satellite contract: Server::stats() is exactly
        // EngineStats::merged over the per-shard snapshots — counters
        // (admissions breakdown) sum to the submitted total, gauges
        // and the shared queue_peak survive as maxes
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, ServePolicy {
            shards: 3,
            ..policy(2, ServeMode::Continuous)
        });
        let rxs: Vec<_> = (0..9u32)
            .map(|i| server.submit(vec![i % 32, 3], 4).unwrap().1)
            .collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        // all completions received => every shard is idle: snapshots
        // taken now are final and mutually consistent
        let per_shard = server.shard_stats();
        assert_eq!(per_shard.len(), 3);
        let merged = server.stats();
        assert_eq!(merged, EngineStats::merged(&per_shard));
        assert_eq!(merged.admissions, 9);
        assert_eq!(
            merged.admissions,
            per_shard.iter().map(|s| s.admissions).sum::<u64>(),
            "per-shard admissions must partition the total"
        );
        assert_eq!(merged.latency_samples(), 9,
                   "every completion lands in the latency histogram");
        // at least one push saw a non-empty queue, and the shared
        // queue's peak is stamped identically onto every shard
        assert!(merged.queue_peak >= 1, "{merged:?}");
        assert!(per_shard.iter().all(|s| s.queue_peak == merged.queue_peak));
        assert_eq!(
            merged.max_active,
            per_shard.iter().map(|s| s.max_active).max().unwrap(),
        );
        server.shutdown();
    }

    #[test]
    fn sharded_shutdown_drains_queued_requests() {
        // shutdown with requests still queued and 2 shards racing the
        // drain: every receiver must still get its completion (the
        // loom model pins the protocol; this exercises the real build)
        let model = toy_model(FfnBackend::Dense);
        let expected = model.generate(&[1, 2], 3);
        let server = Server::start(model, ServePolicy {
            shards: 2,
            ..policy(1, ServeMode::Continuous)
        });
        let rxs: Vec<_> =
            (0..6).map(|_| server.submit(vec![1, 2], 3).unwrap().1).collect();
        server.shutdown();
        for rx in rxs {
            let c = rx.recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!(c.tokens, expected);
        }
    }

    #[test]
    fn sharded_sequential_mode_matches_generate() {
        // the legacy path shards too: batches are collected
        // exactly-once through the same queue
        let model = toy_model(FfnBackend::Dense);
        let expected = model.generate(&[5, 7], 4);
        let server = Server::start(model, ServePolicy {
            shards: 2,
            ..policy(2, ServeMode::Sequential)
        });
        let rxs: Vec<_> =
            (0..6).map(|_| server.submit(vec![5, 7], 4).unwrap().1).collect();
        for rx in rxs {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.tokens, expected);
        }
        server.shutdown();
    }

    #[test]
    fn routed_decode_serves_bit_exact_tokens_and_counts_dispatch() {
        // force routing on every pure-decode step (threshold 1.0): the
        // served stream must still be token-for-token what `generate`
        // produces (the routed kernel is bit-exact with the fused row
        // path), decode steps must land on the routed counter, and the
        // multi-token prefill feed must land on the fallback counter
        // with no density measured for it
        let model = toy_model(FfnBackend::Twell);
        let reference = model.generate(&[1, 2, 3], 4);
        let server = Server::start(model, ServePolicy {
            route_density: 1.0,
            ..policy(2, ServeMode::Continuous)
        });
        let (_, rx) = server.submit(vec![1, 2, 3], 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        let st = server.stats();
        assert!(st.ffn_routed > 0, "routing never engaged: {st:?}");
        assert!(st.ffn_fallback > 0,
                "the prefill chunk should fall back: {st:?}");
        assert_eq!(st.ffn_row + st.ffn_col, 0,
                   "routing enabled on TwELL never reaches the \
                    unrouted counters: {st:?}");
        assert_eq!(st.union_density_calls, st.ffn_routed,
                   "density is measured exactly once per routed step \
                    at threshold 1.0: {st:?}");
        let d = st.mean_union_density();
        assert!(d > 0.0 && d <= 1.0, "mean union density {d}");
        server.shutdown();
    }

    #[test]
    fn route_density_zero_disables_routing_entirely() {
        let model = toy_model(FfnBackend::Twell);
        let reference = model.generate(&[1, 2, 3], 4);
        let server = Server::start(model, ServePolicy {
            route_density: 0.0,
            ..policy(2, ServeMode::Continuous)
        });
        let (_, rx) = server.submit(vec![1, 2, 3], 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        let st = server.stats();
        assert_eq!(st.ffn_routed, 0, "{st:?}");
        assert_eq!(st.ffn_fallback, 0, "{st:?}");
        assert_eq!(st.union_density_calls, 0, "{st:?}");
        assert!(st.ffn_row + st.ffn_col > 0,
                "disabled routing still counts partitioning: {st:?}");
        assert_eq!(st.mean_union_density(), 0.0);
        server.shutdown();
    }

    fn sampled_params(seed: u64) -> SamplingParams {
        SamplingParams { temperature: 0.8, top_k: 12, top_p: 0.95, seed }
    }

    /// One sampled request through a fresh server; `with_noise` adds
    /// concurrent requests with *different* seeds so the target's slot
    /// genuinely interleaves with divergent traffic (slots=2 forces
    /// mixed batches and backfill).
    fn run_sampled(
        backend: FfnBackend, mode: ServeMode, params: SamplingParams,
        with_noise: bool,
    ) -> Vec<u32> {
        let server = Server::start(toy_model(backend), policy(2, mode));
        let noise: Vec<_> = if with_noise {
            (0..3u64)
                .map(|i| {
                    server
                        .submit_sampled(
                            vec![2 + i as u32, 5],
                            6,
                            sampled_params(1000 + i),
                        )
                        .unwrap()
                        .1
                })
                .collect()
        } else {
            Vec::new()
        };
        let (_, rx) =
            server.submit_sampled(vec![1, 2, 3, 4], 8, params).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        for rx in noise {
            rx.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        server.shutdown();
        c.tokens
    }

    /// The sampling determinism contract: the same `(seed, prompt)`
    /// produces the identical token stream on the sequential and the
    /// batched scheduler path, with or without concurrent
    /// divergent-seed traffic — because both paths produce bit-exact
    /// logits (the greedy parity family) and the request's private RNG
    /// consumes exactly one draw per token.
    fn seeded_stream_parity(backend: FfnBackend) {
        let params = sampled_params(0xC0FFEE);
        let seq =
            run_sampled(backend, ServeMode::Sequential, params, false);
        let cont =
            run_sampled(backend, ServeMode::Continuous, params, false);
        let noisy =
            run_sampled(backend, ServeMode::Continuous, params, true);
        assert_eq!(seq.len(), 8);
        assert_eq!(seq, cont,
                   "sequential vs batched diverged ({backend:?})");
        assert_eq!(cont, noisy,
                   "concurrent traffic perturbed the stream ({backend:?})");
        let again =
            run_sampled(backend, ServeMode::Continuous, params, true);
        assert_eq!(cont, again, "same seed failed to reproduce");
    }

    #[test]
    fn seeded_stream_parity_dense() {
        seeded_stream_parity(FfnBackend::Dense);
    }

    #[test]
    fn seeded_stream_parity_twell() {
        seeded_stream_parity(FfnBackend::Twell);
    }

    /// `temperature == 0` must be bit-exact with `greedy_reference`
    /// regardless of top-k / top-p, on both scheduler paths and both
    /// FFN backends — the short-circuit never reaches the pipeline.
    fn temperature_zero_matches_greedy(backend: FfnBackend) {
        let expected = {
            let model = toy_model(backend);
            greedy_reference(&model, &[3, 14, 15], 6).unwrap()
        };
        let params = SamplingParams {
            temperature: 0.0, top_k: 3, top_p: 0.5, seed: 999,
        };
        for mode in [ServeMode::Sequential, ServeMode::Continuous] {
            let server = Server::start(toy_model(backend), policy(2, mode));
            let (_, rx) =
                server.submit_sampled(vec![3, 14, 15], 6, params).unwrap();
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.tokens, expected,
                       "t=0 != greedy ({backend:?}, {mode:?})");
            server.shutdown();
        }
    }

    #[test]
    fn temperature_zero_matches_greedy_dense() {
        temperature_zero_matches_greedy(FfnBackend::Dense);
    }

    #[test]
    fn temperature_zero_matches_greedy_twell() {
        temperature_zero_matches_greedy(FfnBackend::Twell);
    }

    #[test]
    fn different_seeds_diverge_under_high_temperature() {
        // the whole point of per-request sampling: divergent decode
        // traffic.  Six seeds at temperature 2 over a 32-token vocab —
        // all-identical streams would mean the seed is being ignored.
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(4, ServeMode::Continuous));
        let rxs: Vec<_> = (0..6u64)
            .map(|seed| {
                let params = SamplingParams {
                    temperature: 2.0, top_k: 0, top_p: 1.0, seed,
                };
                server.submit_sampled(vec![7, 7, 7], 8, params).unwrap().1
            })
            .collect();
        let streams: Vec<Vec<u32>> = rxs
            .into_iter()
            .map(|rx| {
                rx.recv_timeout(Duration::from_secs(60)).unwrap().tokens
            })
            .collect();
        assert!(streams.iter().all(|s| s.len() == 8));
        assert!(streams.iter().any(|s| s != &streams[0]),
                "six seeds produced identical streams: {streams:?}");
        server.shutdown();
    }

    #[test]
    fn invalid_sampling_params_rejected_at_submit() {
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let bad_t = SamplingParams {
            temperature: -0.5,
            ..SamplingParams::greedy()
        };
        assert!(server.submit_sampled(vec![1], 2, bad_t).is_err());
        let bad_p = SamplingParams {
            temperature: 0.7, top_k: 0, top_p: 0.0, seed: 1,
        };
        assert!(server.submit_sampled(vec![1], 2, bad_p).is_err());
        // the server is still healthy: a valid request goes through
        let (_, rx) = server.submit(vec![1], 2).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens.len(), 2);
        server.shutdown();
    }

    #[test]
    fn sampled_streaming_yields_the_completion_tokens() {
        let params = SamplingParams {
            temperature: 0.9, top_k: 6, top_p: 0.9, seed: 4242,
        };
        let server =
            Server::start(toy_model(FfnBackend::Dense), ServePolicy::default());
        let (id, tok_rx, rx) = server
            .submit_streaming_sampled(vec![2, 9, 4], 6, params)
            .unwrap();
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let streamed: Vec<Token> = tok_rx.try_iter().collect();
        assert_eq!(streamed.len(), c.tokens.len());
        for (i, t) in streamed.iter().enumerate() {
            assert_eq!(t.id, id);
            assert_eq!(t.index, i);
            assert_eq!(t.token, c.tokens[i]);
        }
        // ...and the stream is seed-reproducible on a fresh server
        let server2 =
            Server::start(toy_model(FfnBackend::Dense), ServePolicy::default());
        let (_, rx2) =
            server2.submit_sampled(vec![2, 9, 4], 6, params).unwrap();
        let c2 = rx2.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c2.tokens, c.tokens);
        server.shutdown();
        server2.shutdown();
    }

    /// Chunk 1 (the old token-by-token path), one KV block, and a
    /// chunk larger than every prompt must all serve bit-identical
    /// tokens — with slots < requests, so mixed prefill+decode feeds
    /// and ragged spans happen inside one engine step.
    fn chunked_prefill_serving_parity(backend: FfnBackend) {
        let reference_model = toy_model(backend);
        let prompts: Vec<Vec<u32>> = vec![
            (0..17).map(|i| (i * 3 + 1) % 32).collect(),
            vec![5],
            (0..9).map(|i| (i * 7) % 32).collect(),
        ];
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| reference_model.generate(p, 4))
            .collect();
        for prefill_chunk in [1usize, 8, 64] {
            let server = Server::start(toy_model(backend), ServePolicy {
                prefill_chunk,
                ..policy(2, ServeMode::Continuous)
            });
            let rxs: Vec<_> = prompts
                .iter()
                .map(|p| server.submit(p.clone(), 4).unwrap().1)
                .collect();
            for (rx, exp) in rxs.into_iter().zip(&expected) {
                let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                assert_eq!(&c.tokens, exp,
                           "chunk {prefill_chunk} ({backend:?})");
            }
            server.shutdown();
        }
    }

    #[test]
    fn chunked_prefill_serving_parity_dense() {
        chunked_prefill_serving_parity(FfnBackend::Dense);
    }

    #[test]
    fn chunked_prefill_serving_parity_twell() {
        chunked_prefill_serving_parity(FfnBackend::Twell);
    }

    #[test]
    fn prefill_completes_in_ceil_len_over_chunk_steps() {
        // a 13-token prompt through chunk 4: exactly ceil(13/4) = 4
        // prefill chunks (the first token samples on chunk 4), then
        // max_new - 1 = 2 pure decode steps
        let model = toy_model(FfnBackend::Dense);
        let prompt: Vec<u32> = (0..13).map(|i| i % 32).collect();
        let reference = model.generate(&prompt, 3);
        let server = Server::start(model, ServePolicy {
            slots: 1,
            max_wait: Duration::from_millis(2),
            kv_block_size: 4,
            kv_blocks: 8,
            prefill_chunk: 4,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let (_, rx) = server.submit(prompt, 3).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        let st = server.stats();
        assert_eq!(st.prefill_chunks, 4, "ceil(13 / 4) chunks");
        assert_eq!(st.steps, 4 + 2, "chunked prefill + decode steps");
        server.shutdown();
    }

    #[test]
    fn first_token_ms_is_ordered_between_queue_and_total() {
        // TTFT sanity on both scheduler modes: sampled strictly after
        // dequeue and before the completion is sealed
        for mode in [ServeMode::Sequential, ServeMode::Continuous] {
            let model = toy_model(FfnBackend::Dense);
            let server = Server::start(model, policy(2, mode));
            let rxs: Vec<_> = (0..5u32)
                .map(|i| server.submit(vec![i % 32; 12], 4).unwrap().1)
                .collect();
            let (_, empty_rx) = server.submit(Vec::new(), 4).unwrap();
            for rx in rxs {
                let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                assert!(c.queue_ms <= c.first_token_ms,
                        "{mode:?}: queue {} > first {}",
                        c.queue_ms, c.first_token_ms);
                assert!(c.first_token_ms <= c.total_ms,
                        "{mode:?}: first {} > total {}",
                        c.first_token_ms, c.total_ms);
            }
            // an empty completion never samples: TTFT == total
            let c = empty_rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(c.first_token_ms, c.total_ms);
            server.shutdown();
        }
    }

    #[test]
    fn dropped_receiver_frees_slot_and_blocks_early() {
        // request A reserves the whole pool and would decode for 500
        // tokens; its caller vanishes immediately.  The engine must
        // notice the dead channel, retire A, and hand the blocks to B
        // — not decode A to completion into the void while B starves.
        let model = toy_model(FfnBackend::Dense);
        let expected_b = model.generate(&[4, 9], 4);
        let server = Server::start(model, ServePolicy {
            slots: 2,
            max_wait: Duration::from_millis(2),
            kv_block_size: 16,
            kv_blocks: 32, // 512 positions: exactly A's worst case
            prefill_chunk: 16,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let (_, rx_a) = server.submit(vec![1, 2, 3], 500).unwrap();
        drop(rx_a); // caller abandons A
        let (_, rx_b) = server.submit(vec![4, 9], 4).unwrap();
        let c = rx_b.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, expected_b);
        assert_eq!(server.stats().abandoned, 1);
        server.shutdown();
    }

    #[test]
    fn sequential_shutdown_skips_the_batch_fill_wait() {
        // with a queued request and a huge max_wait, shutdown must not
        // sit out the batch-fill deadline before draining
        let model = toy_model(FfnBackend::Dense);
        let expected = model.generate(&[1, 2], 3);
        let server = Server::start(model, ServePolicy {
            slots: 4,
            max_wait: Duration::from_secs(30),
            kv_block_size: 8,
            kv_blocks: 64,
            prefill_chunk: 8,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Sequential,
        });
        let (_, rx) = server.submit(vec![1, 2], 3).unwrap();
        let t0 = Instant::now();
        server.shutdown(); // joins the workers
        assert!(t0.elapsed() < Duration::from_secs(5),
                "shutdown waited out max_wait: {:?}", t0.elapsed());
        let c = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(c.tokens, expected);
    }

    #[test]
    fn sequential_mode_still_matches_generate() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[5, 7], 4);
        let server = Server::start(model, policy(4, ServeMode::Sequential));
        let (_, rx) = server.submit(vec![5, 7], 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        server.shutdown();
    }

    #[test]
    fn late_arrivals_backfill_freed_slots_mid_flight() {
        // 6 requests through 2 slots, with staggered lengths so no two
        // sequences retire on the same engine step: at least 4
        // admissions must land while the engine is mid-decode on other
        // sequences, and the active set never exceeds the pool
        let model = toy_model(FfnBackend::Dense);
        let expected: Vec<Vec<u32>> =
            (0..6).map(|i| model.generate(&[3, 1], 2 + i)).collect();
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let rxs: Vec<_> =
            (0..6).map(|i| server.submit(vec![3, 1], 2 + i).unwrap().1).collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp);
        }
        let st = server.stats();
        assert_eq!(st.admissions, 6);
        assert!(st.max_active <= 2, "pool overflow: {}", st.max_active);
        assert!(st.backfilled >= 4,
                "expected mid-flight backfills, got {}", st.backfilled);
        assert!(st.steps > 0);
        server.shutdown();
    }

    #[test]
    fn streaming_yields_every_token_before_completion() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[2, 9, 4], 6);
        let server = Server::start(model, ServePolicy::default());
        let (id, tok_rx, rx) =
            server.submit_streaming(vec![2, 9, 4], 6).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let streamed: Vec<Token> = tok_rx.try_iter().collect();
        assert_eq!(c.tokens, reference);
        assert_eq!(streamed.len(), c.tokens.len());
        for (i, t) in streamed.iter().enumerate() {
            assert_eq!(t.id, id);
            assert_eq!(t.index, i);
            assert_eq!(t.token, c.tokens[i]);
        }
        server.shutdown();
    }

    #[test]
    fn long_prompt_served_by_paged_engine_without_fallback() {
        // the acceptance criterion: a request needing more positions
        // than a fixed per-slot share would hold (72 > 128/2 = 64, the
        // old design's max_context) is served by the paged continuous
        // engine itself — bit-exact with generate, zero fallbacks —
        // because it borrows blocks the idle slot isn't using
        let model = toy_model(FfnBackend::Dense);
        let long_prompt: Vec<u32> = (0..70).map(|i| i % 32).collect();
        let reference = model.generate(&long_prompt, 3);
        let server = Server::start(model, ServePolicy {
            slots: 2,
            max_wait: Duration::from_millis(2),
            kv_block_size: 8,
            kv_blocks: 16, // 128 positions pool-wide
            prefill_chunk: 8,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let (_, rx) = server.submit(long_prompt, 3).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        assert_eq!(server.stats().fallbacks, 0);
        server.shutdown();
    }

    #[test]
    fn empty_prompt_gets_empty_completion() {
        // an empty prompt produces no logits, so there is nothing to
        // argmax — the old code fabricated token 0; both scheduler
        // modes must now answer with an empty completion
        for mode in [ServeMode::Sequential, ServeMode::Continuous] {
            let model = toy_model(FfnBackend::Dense);
            let server = Server::start(model, policy(2, mode));
            let (id, rx) = server.submit(Vec::new(), 4).unwrap();
            let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(c.id, id);
            assert!(c.tokens.is_empty(),
                    "{mode:?}: fabricated tokens {:?}", c.tokens);
            assert_eq!(c.prefill_tokens, 0);
            server.shutdown();
        }
    }

    #[test]
    fn request_at_exact_pool_capacity_is_served() {
        // kv_positions_needed(13, 4) = 16 = 4 blocks of 4: fills the
        // pool exactly; an off-by-one in either the allocator or the
        // admission bound would reject or overflow it
        let model = toy_model(FfnBackend::Dense);
        let prompt: Vec<u32> = (0..13).map(|i| i % 32).collect();
        let reference = model.generate(&prompt, 4);
        let server = Server::start(model, ServePolicy {
            slots: 2,
            max_wait: Duration::from_millis(2),
            kv_block_size: 4,
            kv_blocks: 4,
            prefill_chunk: 4,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let (_, rx) = server.submit(prompt, 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        server.shutdown();
    }

    #[test]
    fn admission_waits_for_free_blocks_instead_of_panicking() {
        // each request needs kv_positions_needed(2, 6) = 7 positions =
        // 2 blocks of 4; the pool holds 3 blocks, so only one request
        // fits at a time even though 4 slots exist — later admissions
        // must wait for retiring sequences to free blocks, not panic
        // or overcommit
        let model = toy_model(FfnBackend::Dense);
        let expected: Vec<Vec<u32>> = (0..5u32)
            .map(|i| model.generate(&[i % 32, 3], 6))
            .collect();
        let server = Server::start(model, ServePolicy {
            slots: 4,
            max_wait: Duration::from_millis(2),
            kv_block_size: 4,
            kv_blocks: 3,
            prefill_chunk: 4,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let rxs: Vec<_> = (0..5u32)
            .map(|i| server.submit(vec![i % 32, 3], 6).unwrap().1)
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp);
        }
        let st = server.stats();
        assert_eq!(st.admissions, 5);
        assert_eq!(st.max_active, 1,
                   "block budget must serialize admissions");
        server.shutdown();
    }

    #[test]
    fn impossible_request_rejected_at_submit() {
        // worst case beyond the whole pool (64 blocks x 8 = 512
        // positions) can never be admitted: submit must say so rather
        // than queue the request forever or drop its channel silently
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let err = server.submit(vec![1], 600).unwrap_err();
        assert!(err.to_string().contains("KV positions"), "{err}");
        // a request that exactly fits is still accepted
        assert!(server.submit(vec![1], 512).is_ok());
        // degenerate requests use no KV: exempt from the bound (the
        // engine answers them with an empty completion immediately)
        let (_, rx) = server.submit(Vec::new(), 600).unwrap();
        assert!(rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap()
            .tokens
            .is_empty());
        server.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_requests() {
        // shutdown while requests are still queued: the worker drains
        // the queue before exiting, so every receiver gets its
        // completion (shutdown joins the worker, hence the short
        // post-shutdown recv timeout)
        let model = toy_model(FfnBackend::Dense);
        let expected = model.generate(&[1, 2], 3);
        let server = Server::start(model, policy(1, ServeMode::Continuous));
        let rxs: Vec<_> =
            (0..4).map(|_| server.submit(vec![1, 2], 3).unwrap().1).collect();
        server.shutdown();
        for rx in rxs {
            let c = rx.recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!(c.tokens, expected);
        }
    }

    #[test]
    fn prop_scheduler_preserves_per_submission_results() {
        // property: any submission pattern against any slot count and
        // shard count gets every request answered with the tokens
        // direct generation would produce
        check("continuous scheduler correctness", 5, 31, |g: &mut Gen| {
            let model = toy_model(FfnBackend::Dense);
            let n_req = g.usize_in(1, 6);
            let mut expected = Vec::new();
            let mut prompts = Vec::new();
            for _ in 0..n_req {
                let len = g.usize_in(1, 4);
                let prompt: Vec<u32> = (0..len)
                    .map(|_| g.rng.below(32))
                    .collect();
                expected.push(model.generate(&prompt, 2));
                prompts.push(prompt);
            }
            let server = Server::start(model, ServePolicy {
                shards: g.usize_in(1, 3),
                ..policy(g.usize_in(1, 4), ServeMode::Continuous)
            });
            let rxs: Vec<_> = prompts
                .into_iter()
                .map(|p| server.submit(p, 2).map(|r| r.1))
                .collect();
            for (rx, exp) in rxs.into_iter().zip(&expected) {
                let rx = rx.map_err(|e| format!("submit: {e}"))?;
                let c = rx
                    .recv_timeout(Duration::from_secs(60))
                    .map_err(|e| format!("timeout: {e}"))?;
                if &c.tokens != exp {
                    return Err("served tokens != direct tokens".into());
                }
            }
            server.shutdown();
            Ok(())
        });
    }

    /// The tentpole acceptance criterion: the same prompt set produces
    /// bit-identical token streams with prefix caching on and off —
    /// Dense and TwELL, shards {1, 2} — because sharing changes block
    /// *placement* only, never kernels or accumulation order.  The
    /// workload is built to genuinely engage sharing: a donor request
    /// completes alone (its blocks retire into the cache), then a wave
    /// reuses the same multi-block prefix with divergent tails.
    fn prefix_cache_on_off_bit_identical(backend: FfnBackend) {
        let prefix: Vec<u32> = (0..20).map(|i| (i * 5 + 2) % 32).collect();
        let tails: Vec<Vec<u32>> =
            vec![vec![], vec![1, 2, 3], vec![9], vec![30, 4, 17, 8]];
        let prompts: Vec<Vec<u32>> = tails
            .iter()
            .map(|t| prefix.iter().chain(t.iter()).copied().collect())
            .collect();
        let reference_model = toy_model(backend);
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| reference_model.generate(p, 4))
            .collect();
        let run = |shards: usize, prefix_cache: bool| -> Vec<Vec<u32>> {
            let server = Server::start(toy_model(backend), ServePolicy {
                shards,
                prefix_cache,
                ..policy(2, ServeMode::Continuous)
            });
            // donor first, alone, so the prefix is already cached when
            // the wave arrives
            let (_, rx) = server.submit(prompts[0].clone(), 4).unwrap();
            let donor = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            let mut out = vec![donor.tokens];
            let rxs: Vec<_> = prompts[1..]
                .iter()
                .map(|p| server.submit(p.clone(), 4).unwrap().1)
                .collect();
            for rx in rxs {
                let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                out.push(c.tokens);
            }
            let st = server.stats();
            if prefix_cache && shards == 1 {
                // one shard sees every request: the donor's cached
                // prefix must be found (at 2 shards placement decides
                // which cache a request lands in, so no hit guarantee)
                assert!(st.prefix_hits > 0,
                        "sharing never engaged: {st:?}");
                assert!(st.prefix_blocks_shared > 0, "{st:?}");
            }
            if !prefix_cache {
                assert_eq!(st.prefix_hits, 0, "{st:?}");
                assert_eq!(st.prefix_blocks_shared, 0, "{st:?}");
                assert_eq!(st.cow_copies, 0, "{st:?}");
            }
            server.shutdown();
            out
        };
        for shards in [1usize, 2] {
            let on = run(shards, true);
            let off = run(shards, false);
            assert_eq!(on, off,
                       "prefix cache on/off diverged at {shards} shards \
                        ({backend:?})");
            assert_eq!(on, expected,
                       "served != generate at {shards} shards ({backend:?})");
        }
    }

    #[test]
    fn prefix_cache_on_off_bit_identical_dense() {
        prefix_cache_on_off_bit_identical(FfnBackend::Dense);
    }

    #[test]
    fn prefix_cache_on_off_bit_identical_twell() {
        prefix_cache_on_off_bit_identical(FfnBackend::Twell);
    }

    #[test]
    fn full_prefix_hit_skips_straight_to_the_last_token() {
        // 24-token prompt, block = chunk = 8: request A prefills cold
        // in ceil(24/8) = 3 chunks and retires its blocks into the
        // cache.  An identical request B attaches blocks 0-1 (16
        // positions) and copies 7 rows of block 2 (the copy budget
        // keeps one row back so the final prompt token recomputes and
        // yields B's first logits): B's whole prefill is one 1-token
        // chunk, and its latency ordering still holds.
        let model = toy_model(FfnBackend::Dense);
        let prompt: Vec<u32> = (0..24).map(|i| (i * 3 + 1) % 32).collect();
        let reference = model.generate(&prompt, 3);
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let (_, rx_a) = server.submit(prompt.clone(), 3).unwrap();
        let a = rx_a.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(a.tokens, reference);
        assert_eq!(server.stats().prefill_chunks, 3,
                   "cold prefill takes ceil(24 / 8) chunks");
        let (_, rx_b) = server.submit(prompt, 3).unwrap();
        let b = rx_b.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(b.tokens, reference, "warm stream != cold stream");
        assert_eq!(b.prefill_tokens, 24);
        assert!(b.queue_ms <= b.first_token_ms,
                "queue {} > first {}", b.queue_ms, b.first_token_ms);
        assert!(b.first_token_ms <= b.total_ms,
                "first {} > total {}", b.first_token_ms, b.total_ms);
        let st = server.stats();
        assert_eq!(st.prefill_chunks, 4,
                   "the warm prefill collapses to a single chunk: {st:?}");
        assert_eq!(st.prefix_hits, 1, "{st:?}");
        assert_eq!(st.prefix_blocks_shared, 2, "{st:?}");
        assert_eq!(st.cow_copies, 1, "{st:?}");
        assert!(st.kv_blocks_peak >= 4, "{st:?}");
        server.shutdown();
    }

    #[test]
    fn abandoned_sharing_sequence_releases_its_refcounts() {
        // donor A seeds the cache; sharer B attaches to A's retired
        // blocks and its caller vanishes immediately.  The engine must
        // retire B — dropping the shared refcounts back to zero — and
        // still serve an identical later request C correctly off the
        // same cached prefix.
        let model = toy_model(FfnBackend::Dense);
        let prompt: Vec<u32> = (0..24).map(|i| (i * 7 + 5) % 32).collect();
        let reference = model.generate(&prompt, 4);
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let (_, rx_a) = server.submit(prompt.clone(), 4).unwrap();
        let a = rx_a.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(a.tokens, reference);
        let (_, rx_b) = server.submit(prompt.clone(), 200).unwrap();
        drop(rx_b); // the caller abandons a sequence that shares blocks
        let (_, rx_c) = server.submit(prompt, 4).unwrap();
        let c = rx_c.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        let st = server.stats();
        assert_eq!(st.abandoned, 1, "{st:?}");
        // C must still find the prefix (B, if it counted a hit before
        // being reaped, adds at most one more)
        assert!(st.prefix_hits >= 1, "{st:?}");
        server.shutdown();
    }

    #[test]
    fn one_thread_total_across_four_shards_still_serves() {
        // the `--threads 1 --shards 4` CLI combination: the per-shard
        // budget clamps to one partition per shard instead of a
        // zero-thread pool, and the served streams stay pinned to
        // `generate`
        let _g = crate::sparse::par::test_guard();
        let orig = crate::sparse::par::num_threads();
        let per = crate::sparse::par::threads_per_shard(1, 4);
        assert_eq!(per, 1, "budget below the shard count clamps to 1");
        crate::sparse::par::set_threads(per);
        let model = toy_model(FfnBackend::Twell);
        let expected: Vec<Vec<u32>> = (0..8u32)
            .map(|i| model.generate(&[i % 32, 5, 9], 4))
            .collect();
        let server = Server::start(model, ServePolicy {
            shards: 4,
            ..policy(2, ServeMode::Continuous)
        });
        let rxs: Vec<_> = (0..8u32)
            .map(|i| server.submit(vec![i % 32, 5, 9], 4).unwrap().1)
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp);
        }
        server.shutdown();
        crate::sparse::par::set_threads(orig);
    }

    #[test]
    fn zero_max_new_is_answered_at_the_submit_boundary() {
        // satellite contract: a zero-token request has a fully
        // determined answer, so it completes synchronously at submit —
        // it never rides the queue, can never be shed or refused Busy,
        // and the engine never sees it
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let (id, rx) = server.submit(vec![1, 2, 3], 0).unwrap();
        let c = rx.try_recv().expect("completion ready before submit returns");
        assert_eq!(c.id, id);
        assert!(c.tokens.is_empty());
        assert_eq!(c.prefill_tokens, 3);
        assert_eq!(c.finish, FinishReason::Length);
        assert_eq!(c.first_token_ms, c.total_ms);
        assert_eq!(server.queue_len(), 0, "must never be queued");
        assert_eq!(server.stats().admissions, 0, "engine never saw it");
        // streaming variant: the token stream ends immediately, empty
        let (_, tok_rx, rx2) = server.submit_streaming(vec![9], 0).unwrap();
        assert!(rx2.try_recv().unwrap().tokens.is_empty());
        assert!(tok_rx.try_iter().next().is_none());
        server.shutdown();
    }

    #[test]
    fn chaos_deadline_storm_sheds_everything_and_frees_the_pool() {
        // a storm of requests whose deadlines have already passed when
        // the first admission scan sees them: every one must be shed
        // with DeadlineExceeded before touching a slot or a KV block,
        // and afterwards a request needing the ENTIRE pool must be
        // served bit-exactly — the strongest possible "the pool is
        // fully free" witness
        let model = toy_model(FfnBackend::Dense);
        let filler: Vec<u32> = (0..13).map(|i| i % 32).collect();
        let filler_expected = model.generate(&filler, 4);
        let server = Server::start(model, ServePolicy {
            slots: 2,
            max_wait: Duration::from_millis(2),
            kv_block_size: 4,
            kv_blocks: 4, // 16 positions: filler takes all of them
            prefill_chunk: 4,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let opts = SubmitOptions {
            deadline: Some(Instant::now()), // passed by scan time
            max_queue_wait: None,
        };
        let rxs: Vec<_> = (0..8u32)
            .map(|i| {
                server
                    .submit_opts(
                        vec![i % 32, 3], 6,
                        SamplingParams::greedy(), opts,
                    )
                    .unwrap()
                    .1
            })
            .collect();
        for rx in rxs {
            let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(c.finish, FinishReason::DeadlineExceeded, "{c:?}");
            assert!(c.tokens.is_empty(), "shed before decoding: {c:?}");
            assert!(c.queue_ms <= c.total_ms);
        }
        let st = server.stats();
        assert_eq!(st.shed_deadline, 8, "{st:?}");
        assert_eq!(st.admissions, 0, "a shed request is never admitted");
        // kv_positions_needed(13, 4) = 16 = the whole pool: this can
        // only be admitted if the storm left every block free
        let (_, rx) = server.submit(filler, 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, filler_expected);
        assert_eq!(c.finish, FinishReason::Length);
        server.shutdown();
    }

    #[test]
    fn chaos_deadline_aborts_mid_decode_and_frees_the_blocks() {
        // a request that cannot possibly finish 3800 decode steps
        // inside a 30ms deadline: it is admitted (fresh server, cold
        // estimator, deadline still ahead), decodes until the deadline
        // passes, then is aborted with its partial tokens and its
        // blocks freed.  Under extreme scheduling delay the admission
        // sweep may shed it before it ever starts — also
        // DeadlineExceeded, so the assertion covers both outcomes.
        let model = toy_model(FfnBackend::Dense);
        let check_expected = model.generate(&[4, 5], 4);
        let server = Server::start(model, ServePolicy {
            slots: 2,
            max_wait: Duration::from_millis(2),
            kv_block_size: 16,
            kv_blocks: 256, // 4096 positions: room for the long request
            prefill_chunk: 16,
            route_density: 0.25,
            shards: 1,
            prefix_cache: true,
            max_queue: 0,
            mode: ServeMode::Continuous,
        });
        let opts = SubmitOptions {
            deadline: Some(Instant::now() + Duration::from_millis(30)),
            max_queue_wait: None,
        };
        let (_, rx) = server
            .submit_opts(vec![7, 8, 9], 3800, SamplingParams::greedy(), opts)
            .unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.finish, FinishReason::DeadlineExceeded, "{:?}",
                   (c.tokens.len(), c.total_ms));
        assert!(c.tokens.len() < 3800, "deadline never enforced");
        let st = server.stats();
        assert_eq!(st.deadline_aborts + st.shed_deadline, 1, "{st:?}");
        // the aborted sequence's blocks are back: a normal request
        // completes bit-exactly
        let (_, rx) = server.submit(vec![4, 5], 4).unwrap();
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, check_expected);
        server.shutdown();
    }

    #[test]
    fn chaos_busy_shed_burst_leaves_accepted_streams_unaffected() {
        // queue bounded at 2, one slot occupied by a long request:
        // with the queue full, a burst of non-blocking submits must be
        // refused Busy immediately, a bounded-wait submit must shed
        // after its wait budget, and every ACCEPTED request must still
        // complete bit-exactly — load shedding cannot perturb admitted
        // work
        let model = toy_model(FfnBackend::Dense);
        let expected_long = model.generate(&[1, 2, 3], 200);
        let expected_short = model.generate(&[4, 5], 3);
        let server = Server::start(model, ServePolicy {
            max_queue: 2,
            ..policy(1, ServeMode::Continuous)
        });
        // occupy the single slot; the first streamed token proves the
        // request is decoding (i.e. it left the queue)
        let (_, tok_rx, rx_long) =
            server.submit_streaming(vec![1, 2, 3], 200).unwrap();
        tok_rx.recv_timeout(Duration::from_secs(30)).unwrap();
        // fill the queue to its cap behind the busy slot
        let rx_q1 = server.submit(vec![4, 5], 3).unwrap().1;
        let rx_q2 = server.submit(vec![4, 5], 3).unwrap().1;
        // burst: every non-blocking submit bounces without queueing
        for _ in 0..5 {
            let r = server.try_submit_sampled(
                vec![4, 5], 3,
                SamplingParams::greedy(), SubmitOptions::default(),
            );
            assert!(matches!(r, Err(SubmitError::Busy)), "queue was full");
        }
        // a bounded-wait blocking submit sheds once its budget expires
        // (the long request still has ~190 tokens to go)
        let r = server.submit_opts(
            vec![4, 5], 3, SamplingParams::greedy(),
            SubmitOptions {
                deadline: None,
                max_queue_wait: Some(Duration::from_millis(5)),
            },
        );
        assert!(matches!(r, Err(SubmitError::Busy)), "wait never expired");
        // accepted work is untouched by all of the above
        let c = rx_long.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, expected_long);
        assert_eq!(c.finish, FinishReason::Length);
        for rx in [rx_q1, rx_q2] {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.tokens, expected_short);
            assert_eq!(c.finish, FinishReason::Length);
        }
        let st = server.stats();
        assert_eq!(st.queue_rejections, 5, "{st:?}");
        assert_eq!(st.shed_busy, 1, "{st:?}");
        assert_eq!(st.queue_peak, 2, "the cap was never exceeded: {st:?}");
        server.shutdown();
    }

    /// The shard-panic acceptance criterion.  Feature-gated: arming a
    /// failpoint on a live engine site is process-global, so this only
    /// runs in the serialized `--features failpoints` chaos job (see
    /// `.github/workflows/analysis.yml`), never in tier-1's parallel
    /// test run.
    #[cfg(feature = "failpoints")]
    #[test]
    fn chaos_shard_panic_fails_in_flight_and_restarts_the_shard() {
        use crate::util::failpoint;
        let model = toy_model(FfnBackend::Dense);
        let prompts: Vec<Vec<u32>> =
            (0..4u32).map(|i| vec![i + 1, 2, 3]).collect();
        let expected: Vec<Vec<u32>> =
            prompts.iter().map(|p| model.generate(p, 4)).collect();
        let server = Server::start(model, policy(1, ServeMode::Continuous));
        failpoint::reset();
        // fire on the 2nd engine step: request 0 (the only admitted
        // one — a single slot) is mid-decode when the shard dies
        failpoint::arm("engine-step", 2);
        let rxs: Vec<_> = prompts
            .iter()
            .map(|p| server.submit(p.clone(), 4).unwrap().1)
            .collect();
        let cs: Vec<Completion> = rxs
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap())
            .collect();
        failpoint::reset();
        // the in-flight request is failed by the supervisor...
        assert_eq!(cs[0].finish, FinishReason::ShardFailed, "{:?}", cs[0]);
        assert!(cs[0].tokens.is_empty());
        // ...and every surviving stream is bit-identical to an
        // unfaulted run: the restarted shard serves them off a fresh
        // KV pool with nothing perturbed
        for (c, exp) in cs[1..].iter().zip(&expected[1..]) {
            assert_eq!(c.finish, FinishReason::Length, "{c:?}");
            assert_eq!(&c.tokens, exp,
                       "restart perturbed a surviving stream");
        }
        let st = server.stats();
        assert_eq!(st.shard_restarts, 1, "{st:?}");
        server.shutdown();
    }
}
