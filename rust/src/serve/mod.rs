//! Serving layer: request router + dynamic batcher over the rust
//! inference engine (the vllm-router-shaped L3 component).
//!
//! Requests enter a shared queue; the worker drains up to
//! `max_batch` requests per cycle (waiting at most `max_wait` for the
//! batch to fill), pads them to a common length, runs prefill through the
//! batched forward (dense or TwELL backend), then decodes each request
//! greedily with its KV cache.  Completions return through per-request
//! channels.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::kv::{argmax, KvCache};
use crate::model::Model;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub prefill_tokens: usize,
}

struct Pending {
    req: Request,
    enqueued: Instant,
    tx: Sender<Completion>,
}

#[derive(Default)]
struct Queue {
    items: VecDeque<Pending>,
}

/// Dynamic batching policy (the tunables figure 5's serving analogue
/// sweeps).
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

pub struct Server {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stop: Arc<AtomicBool>,
    next_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    pub policy: BatchPolicy,
}

impl Server {
    /// Spawn the worker thread owning the model.
    pub fn start(model: Model, policy: BatchPolicy) -> Server {
        let queue = Arc::new((Mutex::new(Queue::default()), Condvar::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let q2 = queue.clone();
        let s2 = stop.clone();
        let worker = std::thread::spawn(move || {
            worker_loop(model, q2, s2, policy);
        });
        Server {
            queue,
            stop,
            next_id: AtomicU64::new(0),
            worker: Some(worker),
            policy,
        }
    }

    /// Enqueue a request; returns (id, completion receiver).
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize)
        -> (u64, Receiver<Completion>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let (lock, cv) = &*self.queue;
        lock.lock().unwrap().items.push_back(Pending {
            req: Request { id, prompt, max_new },
            enqueued: Instant::now(),
            tx,
        });
        cv.notify_one();
        (id, rx)
    }

    pub fn queue_len(&self) -> usize {
        self.queue.0.lock().unwrap().items.len()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.1.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.1.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: Model, queue: Arc<(Mutex<Queue>, Condvar)>, stop: Arc<AtomicBool>,
    policy: BatchPolicy,
) {
    loop {
        // collect a batch: block for the first item, then wait up to
        // max_wait for more
        let batch: Vec<Pending> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while q.items.is_empty() && !stop.load(Ordering::Relaxed) {
                let (qq, _timeout) =
                    cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = qq;
            }
            if stop.load(Ordering::Relaxed) && q.items.is_empty() {
                return;
            }
            let deadline = Instant::now() + policy.max_wait;
            while q.items.len() < policy.max_batch
                && Instant::now() < deadline
            {
                let (qq, timeout) = cv
                    .wait_timeout(q, deadline - Instant::now())
                    .unwrap();
                q = qq;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.items.len().min(policy.max_batch);
            q.items.drain(..take).collect()
        };
        if batch.is_empty() {
            continue;
        }
        serve_batch(&model, batch);
    }
}

/// Run one collected batch: per-request KV prefill + greedy decode.
fn serve_batch(model: &Model, batch: Vec<Pending>) {
    for p in batch {
        let t0 = Instant::now();
        let queue_ms = p.enqueued.elapsed().as_secs_f64() * 1e3
            - t0.elapsed().as_secs_f64() * 1e3;
        let mut cache =
            KvCache::new(model, p.req.prompt.len() + p.req.max_new + 1);
        let mut logits = vec![0f32; model.cfg.vocab_size];
        for &t in &p.req.prompt {
            logits = model.decode_step(&mut cache, t);
        }
        let mut tokens = Vec::with_capacity(p.req.max_new);
        for _ in 0..p.req.max_new {
            let next = argmax(&logits) as u32;
            tokens.push(next);
            logits = model.decode_step(&mut cache, next);
        }
        let _ = p.tx.send(Completion {
            id: p.req.id,
            tokens,
            queue_ms: queue_ms.max(0.0),
            total_ms: p.enqueued.elapsed().as_secs_f64() * 1e3,
            prefill_tokens: p.req.prompt.len(),
        });
    }
}

/// Latency/throughput aggregation for the serving example + benches.
#[derive(Default, Debug)]
pub struct ServeMetrics {
    pub completions: Vec<Completion>,
}

impl ServeMetrics {
    pub fn record(&mut self, c: Completion) {
        self.completions.push(c);
    }

    pub fn p50_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms).map(|l| crate::util::stats::median(&l))
            .unwrap_or(0.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::percentile(&l, 99.0))
            .unwrap_or(0.0)
    }

    pub fn throughput_tok_s(&self, wall_s: f64) -> f64 {
        let toks: usize = self
            .completions
            .iter()
            .map(|c| c.tokens.len() + c.prefill_tokens)
            .sum();
        toks as f64 / wall_s
    }

    fn latencies(&self, f: impl Fn(&Completion) -> f64) -> Option<Vec<f64>> {
        if self.completions.is_empty() {
            return None;
        }
        Some(self.completions.iter().map(f).collect())
    }
}

/// Re-exported for tests/benches: deterministic result check.
pub fn greedy_reference(model: &Model, prompt: &[u32], max_new: usize)
    -> Result<Vec<u32>> {
    Ok(model.generate(prompt, max_new))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;
    use crate::model::FfnBackend;
    use crate::util::prop::{check, Gen};

    #[test]
    fn server_round_trip_matches_direct_generate() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[1, 2, 3], 4);
        let server = Server::start(model, BatchPolicy::default());
        let (_, rx) = server.submit(vec![1, 2, 3], 4);
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        assert_eq!(c.prefill_tokens, 3);
        server.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(
            model,
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
        );
        let mut rxs = Vec::new();
        for i in 0..20u32 {
            let (id, rx) = server.submit(vec![i % 32, (i + 1) % 32], 3);
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.id, id);
            assert_eq!(c.tokens.len(), 3);
        }
        assert_eq!(server.queue_len(), 0);
        server.shutdown();
    }

    #[test]
    fn twell_backend_serves_identically() {
        let md = toy_model(FfnBackend::Dense);
        let reference = md.generate(&[5, 7], 4);
        let mt = toy_model(FfnBackend::Twell);
        let server = Server::start(mt, BatchPolicy::default());
        let (_, rx) = server.submit(vec![5, 7], 4);
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        server.shutdown();
    }

    #[test]
    fn prop_batcher_preserves_per_submission_results() {
        // property: any submission pattern gets every request answered
        // with the same tokens direct generation would produce
        check("batcher correctness", 5, 31, |g: &mut Gen| {
            let model = toy_model(FfnBackend::Dense);
            let n_req = g.usize_in(1, 6);
            let mut expected = Vec::new();
            let mut prompts = Vec::new();
            for _ in 0..n_req {
                let len = g.usize_in(1, 4);
                let prompt: Vec<u32> = (0..len)
                    .map(|_| g.rng.below(32))
                    .collect();
                expected.push(model.generate(&prompt, 2));
                prompts.push(prompt);
            }
            let server = Server::start(
                model,
                BatchPolicy {
                    max_batch: g.usize_in(1, 4),
                    max_wait: Duration::from_millis(1),
                },
            );
            let rxs: Vec<_> = prompts
                .into_iter()
                .map(|p| server.submit(p, 2).1)
                .collect();
            for (rx, exp) in rxs.into_iter().zip(&expected) {
                let c = rx
                    .recv_timeout(Duration::from_secs(60))
                    .map_err(|e| format!("timeout: {e}"))?;
                if &c.tokens != exp {
                    return Err("served tokens != direct tokens".into());
                }
            }
            server.shutdown();
            Ok(())
        });
    }
}
