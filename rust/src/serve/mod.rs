//! Serving layer: continuous-batching inference engine over the rust
//! model (the vllm-shaped L3 component).
//!
//! Requests enter a shared queue; the worker thread owns the model plus
//! a fixed pool of KV *slots* (`BatchKvCache`).  Every engine iteration
//! it (1) admits queued requests into free slots — no batch barrier, a
//! request never waits for the current batch to finish — (2) advances
//! all active slots one token with `Model::decode_step_batch`, which
//! feeds the FFN backends a `(B_active, d)` activation matrix (so the
//! TwELL pipeline finally runs batched during decode), and (3) retires
//! finished sequences immediately, backfilling their slots from the
//! queue on the next iteration.  Prefill is interleaved token-by-token
//! with decode (Orca-style iteration-level scheduling), so short and
//! long requests share the engine without head-of-line blocking.
//!
//! Per-token streaming: `submit_streaming` returns a `Receiver<Token>`
//! that yields each generated token as it is chosen, alongside the
//! final `Completion`.
//!
//! The pre-refactor collect-then-serialize path is kept behind
//! `ServeMode::Sequential` as the parity baseline; oversized requests
//! (prompt + max_new beyond the slot capacity) fall back to it
//! transparently.  Both paths are greedy and share `greedy_decode`, so
//! served tokens are bit-exact with `Model::generate`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::model::kv::{argmax, greedy_decode, BatchKvCache};
use crate::model::Model;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub prefill_tokens: usize,
}

/// One streamed token, sent the moment the engine samples it.
#[derive(Clone, Copy, Debug)]
pub struct Token {
    pub id: u64,
    /// 0-based index within the generated tokens
    pub index: usize,
    pub token: u32,
}

struct Pending {
    req: Request,
    enqueued: Instant,
    tx: Sender<Completion>,
    stream: Option<Sender<Token>>,
}

#[derive(Default)]
struct Queue {
    items: VecDeque<Pending>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeMode {
    /// Legacy collect-then-serialize loop (kept for parity testing).
    Sequential,
    /// Slot-based continuous batching (the default).
    Continuous,
}

/// Scheduler tunables (`repro serve` and the serving benches sweep
/// these).
#[derive(Clone, Copy, Debug)]
pub struct ServePolicy {
    /// KV slot pool size: max concurrently decoding sequences
    /// (continuous) or max collected batch (sequential).
    pub slots: usize,
    /// Sequential mode: how long to wait for the batch to fill.
    pub max_wait: Duration,
    /// Per-slot KV capacity; requests needing more positions than this
    /// are served through the sequential fallback.
    pub max_context: usize,
    pub mode: ServeMode,
}

impl Default for ServePolicy {
    fn default() -> Self {
        ServePolicy {
            slots: 8,
            max_wait: Duration::from_millis(5),
            max_context: 512,
            mode: ServeMode::Continuous,
        }
    }
}

/// Engine counters, exposed for tests and the serve CLI.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// requests admitted into a KV slot
    pub admissions: u64,
    /// admissions that landed while other sequences were mid-decode —
    /// i.e. backfills into a freed slot, the no-batch-barrier property
    pub backfilled: u64,
    /// batched decode steps executed
    pub steps: u64,
    /// most simultaneously active slots observed
    pub max_active: usize,
    /// oversized requests routed through the sequential fallback
    pub fallbacks: u64,
}

pub struct Server {
    queue: Arc<(Mutex<Queue>, Condvar)>,
    stop: Arc<AtomicBool>,
    next_id: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<EngineStats>>,
    pub policy: ServePolicy,
}

impl Server {
    /// Spawn the worker thread owning the model.
    pub fn start(model: Model, policy: ServePolicy) -> Server {
        assert!(policy.slots > 0, "need at least one slot");
        let queue = Arc::new((Mutex::new(Queue::default()), Condvar::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let q2 = queue.clone();
        let s2 = stop.clone();
        let st2 = stats.clone();
        let worker = std::thread::spawn(move || match policy.mode {
            ServeMode::Sequential => {
                sequential_loop(model, q2, s2, policy, st2)
            }
            ServeMode::Continuous => {
                continuous_loop(model, q2, s2, policy, st2)
            }
        });
        Server {
            queue,
            stop,
            next_id: AtomicU64::new(0),
            worker: Some(worker),
            stats,
            policy,
        }
    }

    /// Enqueue a request; returns (id, completion receiver).
    pub fn submit(&self, prompt: Vec<u32>, max_new: usize)
        -> (u64, Receiver<Completion>) {
        let (id, _, rx) = self.enqueue(prompt, max_new, false);
        (id, rx)
    }

    /// Enqueue a request with per-token streaming; returns
    /// (id, token receiver, completion receiver).
    pub fn submit_streaming(&self, prompt: Vec<u32>, max_new: usize)
        -> (u64, Receiver<Token>, Receiver<Completion>) {
        let (id, stream_rx, rx) = self.enqueue(prompt, max_new, true);
        (id, stream_rx.unwrap(), rx)
    }

    fn enqueue(&self, prompt: Vec<u32>, max_new: usize, stream: bool)
        -> (u64, Option<Receiver<Token>>, Receiver<Completion>) {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        let (stream_tx, stream_rx) = if stream {
            let (a, b) = channel();
            (Some(a), Some(b))
        } else {
            (None, None)
        };
        let (lock, cv) = &*self.queue;
        lock.lock().unwrap().items.push_back(Pending {
            req: Request { id, prompt, max_new },
            enqueued: Instant::now(),
            tx,
            stream: stream_tx,
        });
        cv.notify_one();
        (id, stream_rx, rx)
    }

    pub fn queue_len(&self) -> usize {
        self.queue.0.lock().unwrap().items.len()
    }

    /// Snapshot of the engine counters.
    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().unwrap()
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.1.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.1.notify_all();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Serve one request start-to-finish on the sequential path.
/// `queue_ms` was measured once, at dequeue.
fn serve_one(model: &Model, p: Pending, queue_ms: f64) {
    let tokens = greedy_decode(model, &p.req.prompt, p.req.max_new,
                               |i, t| {
        if let Some(stream) = &p.stream {
            let _ = stream.send(Token { id: p.req.id, index: i, token: t });
        }
    });
    let _ = p.tx.send(Completion {
        id: p.req.id,
        tokens,
        queue_ms,
        total_ms: p.enqueued.elapsed().as_secs_f64() * 1e3,
        prefill_tokens: p.req.prompt.len(),
    });
}

/// Legacy worker: collect a batch (waiting up to `max_wait` for it to
/// fill), then serve each request sequentially.
fn sequential_loop(
    model: Model, queue: Arc<(Mutex<Queue>, Condvar)>,
    stop: Arc<AtomicBool>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>,
) {
    loop {
        let batch: Vec<Pending> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while q.items.is_empty() && !stop.load(Ordering::Relaxed) {
                let (qq, _timeout) =
                    cv.wait_timeout(q, Duration::from_millis(50)).unwrap();
                q = qq;
            }
            if stop.load(Ordering::Relaxed) && q.items.is_empty() {
                return;
            }
            let deadline = Instant::now() + policy.max_wait;
            while q.items.len() < policy.slots && Instant::now() < deadline
            {
                let (qq, timeout) = cv
                    .wait_timeout(q, deadline - Instant::now())
                    .unwrap();
                q = qq;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = q.items.len().min(policy.slots);
            q.items.drain(..take).collect()
        };
        // queue time ends here, at dequeue — measured exactly once
        let dequeued: Vec<(Pending, f64)> = batch
            .into_iter()
            .map(|p| {
                let q_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
                (p, q_ms)
            })
            .collect();
        for (p, q_ms) in dequeued {
            serve_one(&model, p, q_ms);
            stats.lock().unwrap().admissions += 1;
        }
    }
}

/// Per-slot state of an in-flight sequence.
struct Slot {
    p: Pending,
    queue_ms: f64,
    /// next prompt token index to feed (== prompt.len() once decoding)
    prompt_pos: usize,
    tokens: Vec<u32>,
    /// last sampled token, fed on the next iteration
    next_feed: u32,
}

/// The continuous-batching engine loop.
fn continuous_loop(
    model: Model, queue: Arc<(Mutex<Queue>, Condvar)>,
    stop: Arc<AtomicBool>, policy: ServePolicy,
    stats: Arc<Mutex<EngineStats>>,
) {
    let cap = policy.max_context;
    let mut cache = BatchKvCache::new(&model, policy.slots, cap);
    let mut slots: Vec<Option<Slot>> =
        (0..policy.slots).map(|_| None).collect();
    let mut active = 0usize;
    let model = &model;
    // fallback requests are served on scoped side threads (the model is
    // only ever read), so an oversized prompt never stalls the engine;
    // the scope joins any still-running fallbacks on shutdown
    std::thread::scope(|scope| loop {
        // ---- admission: pull queued requests into free slots ----------
        let admitted: Vec<Pending> = {
            let (lock, cv) = &*queue;
            let mut q = lock.lock().unwrap();
            while active == 0 && q.items.is_empty() {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                let (qq, _) = cv
                    .wait_timeout(q, Duration::from_millis(50))
                    .unwrap();
                q = qq;
            }
            let take = (policy.slots - active).min(q.items.len());
            q.items.drain(..take).collect()
        };
        for p in admitted {
            // queue time ends here, at dequeue — measured exactly once
            let queue_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
            if p.req.max_new == 0 {
                let _ = p.tx.send(Completion {
                    id: p.req.id,
                    tokens: Vec::new(),
                    queue_ms,
                    total_ms: p.enqueued.elapsed().as_secs_f64() * 1e3,
                    prefill_tokens: p.req.prompt.len(),
                });
                continue;
            }
            // needs prompt + max_new - 1 KV positions; oversized or
            // degenerate requests take the sequential fallback
            if p.req.prompt.is_empty()
                || p.req.prompt.len() + p.req.max_new > cap + 1
            {
                stats.lock().unwrap().fallbacks += 1;
                scope.spawn(move || serve_one(model, p, queue_ms));
                continue;
            }
            let si = slots
                .iter()
                .position(|s| s.is_none())
                .expect("admission beyond free slots");
            cache.reset_slot(si);
            // a true backfill: some already-admitted sequence has made
            // progress, i.e. this admission lands mid-decode (not in
            // the same first wave into an idle engine)
            let backfill = slots.iter().flatten().any(|s| {
                s.prompt_pos > 0 || !s.tokens.is_empty()
            });
            slots[si] = Some(Slot {
                p,
                queue_ms,
                prompt_pos: 0,
                tokens: Vec::new(),
                next_feed: 0,
            });
            active += 1;
            let mut st = stats.lock().unwrap();
            st.admissions += 1;
            if backfill {
                st.backfilled += 1;
            }
            st.max_active = st.max_active.max(active);
        }
        if active == 0 {
            continue;
        }

        // ---- one batched engine step over every active slot -----------
        let feeds: Vec<(usize, u32)> = slots
            .iter()
            .enumerate()
            .filter_map(|(si, s)| {
                s.as_ref().map(|s| {
                    let tok = if s.prompt_pos < s.p.req.prompt.len() {
                        s.p.req.prompt[s.prompt_pos]
                    } else {
                        s.next_feed
                    };
                    (si, tok)
                })
            })
            .collect();
        let logits = model.decode_step_batch(&mut cache, &feeds);
        stats.lock().unwrap().steps += 1;

        // ---- sample / retire --------------------------------------------
        for (row, &(si, _)) in feeds.iter().enumerate() {
            let slot = slots[si].as_mut().unwrap();
            if slot.prompt_pos < slot.p.req.prompt.len() {
                slot.prompt_pos += 1;
                if slot.prompt_pos < slot.p.req.prompt.len() {
                    continue; // still prefilling
                }
                // the prompt's last logits arrive this step: fall
                // through and sample the first token
            }
            let next = argmax(logits.row(row)) as u32;
            let index = slot.tokens.len();
            slot.tokens.push(next);
            if let Some(stream) = &slot.p.stream {
                let _ = stream.send(Token {
                    id: slot.p.req.id,
                    index,
                    token: next,
                });
            }
            if slot.tokens.len() >= slot.p.req.max_new {
                // finished: retire immediately, slot backfills next
                // iteration (no batch barrier)
                let s = slots[si].take().unwrap();
                active -= 1;
                let _ = s.p.tx.send(Completion {
                    id: s.p.req.id,
                    tokens: s.tokens,
                    queue_ms: s.queue_ms,
                    total_ms: s.p.enqueued.elapsed().as_secs_f64() * 1e3,
                    prefill_tokens: s.p.req.prompt.len(),
                });
            } else {
                slot.next_feed = next;
            }
        }
    })
}

/// Latency/throughput aggregation for the serving example + benches.
#[derive(Default, Debug)]
pub struct ServeMetrics {
    pub completions: Vec<Completion>,
}

impl ServeMetrics {
    pub fn record(&mut self, c: Completion) {
        self.completions.push(c);
    }

    pub fn p50_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms).map(|l| crate::util::stats::median(&l))
            .unwrap_or(0.0)
    }

    pub fn p95_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::percentile(&l, 95.0))
            .unwrap_or(0.0)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latencies(|c| c.total_ms)
            .map(|l| crate::util::stats::percentile(&l, 99.0))
            .unwrap_or(0.0)
    }

    pub fn throughput_tok_s(&self, wall_s: f64) -> f64 {
        let toks: usize = self
            .completions
            .iter()
            .map(|c| c.tokens.len() + c.prefill_tokens)
            .sum();
        toks as f64 / wall_s
    }

    fn latencies(&self, f: impl Fn(&Completion) -> f64) -> Option<Vec<f64>> {
        if self.completions.is_empty() {
            return None;
        }
        Some(self.completions.iter().map(f).collect())
    }
}

/// Re-exported for tests/benches: deterministic result check.
pub fn greedy_reference(model: &Model, prompt: &[u32], max_new: usize)
    -> Result<Vec<u32>> {
    Ok(model.generate(prompt, max_new))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::tests_support::toy_model;
    use crate::model::FfnBackend;
    use crate::util::prop::{check, Gen};

    fn policy(slots: usize, mode: ServeMode) -> ServePolicy {
        ServePolicy {
            slots,
            max_wait: Duration::from_millis(2),
            max_context: 64,
            mode,
        }
    }

    #[test]
    fn server_round_trip_matches_direct_generate() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[1, 2, 3], 4);
        let server = Server::start(model, ServePolicy::default());
        let (_, rx) = server.submit(vec![1, 2, 3], 4);
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        assert_eq!(c.prefill_tokens, 3);
        server.shutdown();
    }

    #[test]
    fn queue_ms_never_exceeds_total_ms() {
        // both scheduler modes: queue time is measured once at dequeue,
        // so it must be non-negative and bounded by the total latency
        for mode in [ServeMode::Sequential, ServeMode::Continuous] {
            let model = toy_model(FfnBackend::Dense);
            let server = Server::start(model, policy(2, mode));
            let rxs: Vec<_> = (0..6u32)
                .map(|i| server.submit(vec![i % 32, 3], 4).1)
                .collect();
            for rx in rxs {
                let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                assert!(c.queue_ms >= 0.0, "{mode:?}: {}", c.queue_ms);
                assert!(c.queue_ms <= c.total_ms,
                        "{mode:?}: queue {} > total {}",
                        c.queue_ms, c.total_ms);
            }
            server.shutdown();
        }
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let model = toy_model(FfnBackend::Dense);
        let server = Server::start(model, policy(4, ServeMode::Continuous));
        let mut rxs = Vec::new();
        for i in 0..20u32 {
            let (id, rx) = server.submit(vec![i % 32, (i + 1) % 32], 3);
            rxs.push((id, rx));
        }
        for (id, rx) in rxs {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(c.id, id);
            assert_eq!(c.tokens.len(), 3);
        }
        assert_eq!(server.queue_len(), 0);
        server.shutdown();
    }

    /// The headline parity guarantee: N concurrent ragged-length
    /// requests through the continuous engine produce token-for-token
    /// what sequential `generate` produces — for both FFN backends.
    fn continuous_parity(backend: FfnBackend) {
        let reference_model = toy_model(backend);
        let prompts: Vec<Vec<u32>> = vec![
            vec![1, 2, 3, 4, 5, 6, 7],
            vec![9],
            vec![30, 30, 2],
            vec![4, 0, 11, 19, 23],
            vec![8, 8],
        ];
        let max_news = [6usize, 2, 9, 1, 4];
        let expected: Vec<Vec<u32>> = prompts
            .iter()
            .zip(max_news)
            .map(|(p, n)| reference_model.generate(p, n))
            .collect();
        // slots < requests forces mid-flight backfill as well
        let server =
            Server::start(reference_model, policy(2, ServeMode::Continuous));
        let rxs: Vec<_> = prompts
            .iter()
            .zip(max_news)
            .map(|(p, n)| server.submit(p.clone(), n).1)
            .collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp, "served != generate ({backend:?})");
        }
        server.shutdown();
    }

    #[test]
    fn continuous_parity_dense() {
        continuous_parity(FfnBackend::Dense);
    }

    #[test]
    fn continuous_parity_twell() {
        continuous_parity(FfnBackend::Twell);
    }

    #[test]
    fn sequential_mode_still_matches_generate() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[5, 7], 4);
        let server = Server::start(model, policy(4, ServeMode::Sequential));
        let (_, rx) = server.submit(vec![5, 7], 4);
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(c.tokens, reference);
        server.shutdown();
    }

    #[test]
    fn late_arrivals_backfill_freed_slots_mid_flight() {
        // 6 requests through 2 slots, with staggered lengths so no two
        // sequences retire on the same engine step: at least 4
        // admissions must land while the engine is mid-decode on other
        // sequences, and the active set never exceeds the pool
        let model = toy_model(FfnBackend::Dense);
        let expected: Vec<Vec<u32>> =
            (0..6).map(|i| model.generate(&[3, 1], 2 + i)).collect();
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let rxs: Vec<_> =
            (0..6).map(|i| server.submit(vec![3, 1], 2 + i).1).collect();
        for (rx, exp) in rxs.into_iter().zip(&expected) {
            let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(&c.tokens, exp);
        }
        let st = server.stats();
        assert_eq!(st.admissions, 6);
        assert!(st.max_active <= 2, "pool overflow: {}", st.max_active);
        assert!(st.backfilled >= 4,
                "expected mid-flight backfills, got {}", st.backfilled);
        assert!(st.steps > 0);
        server.shutdown();
    }

    #[test]
    fn streaming_yields_every_token_before_completion() {
        let model = toy_model(FfnBackend::Dense);
        let reference = model.generate(&[2, 9, 4], 6);
        let server = Server::start(model, ServePolicy::default());
        let (id, tok_rx, rx) = server.submit_streaming(vec![2, 9, 4], 6);
        let c = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        let streamed: Vec<Token> = tok_rx.try_iter().collect();
        assert_eq!(c.tokens, reference);
        assert_eq!(streamed.len(), c.tokens.len());
        for (i, t) in streamed.iter().enumerate() {
            assert_eq!(t.id, id);
            assert_eq!(t.index, i);
            assert_eq!(t.token, c.tokens[i]);
        }
        server.shutdown();
    }

    #[test]
    fn oversized_request_takes_sequential_fallback() {
        let model = toy_model(FfnBackend::Dense);
        let long_prompt: Vec<u32> = (0..70).map(|i| i % 32).collect();
        let reference = model.generate(&long_prompt, 3);
        // max_context 64 < 70 + 3 - 1 => fallback path
        let server = Server::start(model, policy(2, ServeMode::Continuous));
        let (_, rx) = server.submit(long_prompt, 3);
        let c = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(c.tokens, reference);
        assert_eq!(server.stats().fallbacks, 1);
        server.shutdown();
    }

    #[test]
    fn prop_scheduler_preserves_per_submission_results() {
        // property: any submission pattern against any slot count gets
        // every request answered with the tokens direct generation
        // would produce
        check("continuous scheduler correctness", 5, 31, |g: &mut Gen| {
            let model = toy_model(FfnBackend::Dense);
            let n_req = g.usize_in(1, 6);
            let mut expected = Vec::new();
            let mut prompts = Vec::new();
            for _ in 0..n_req {
                let len = g.usize_in(1, 4);
                let prompt: Vec<u32> = (0..len)
                    .map(|_| g.rng.below(32))
                    .collect();
                expected.push(model.generate(&prompt, 2));
                prompts.push(prompt);
            }
            let server = Server::start(
                model,
                policy(g.usize_in(1, 4), ServeMode::Continuous),
            );
            let rxs: Vec<_> = prompts
                .into_iter()
                .map(|p| server.submit(p, 2).1)
                .collect();
            for (rx, exp) in rxs.into_iter().zip(&expected) {
                let c = rx
                    .recv_timeout(Duration::from_secs(60))
                    .map_err(|e| format!("timeout: {e}"))?;
                if &c.tokens != exp {
                    return Err("served tokens != direct tokens".into());
                }
            }
            server.shutdown();
            Ok(())
        });
    }
}
