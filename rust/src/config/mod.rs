//! Configuration system: model presets (mirroring python/compile/configs.py
//! via the AOT manifest), training hyperparameters, and run descriptions
//! parsed from JSON files or CLI overrides.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Model architecture + kernel parameters.  The authoritative copy lives
/// in the AOT manifest (written by python); this struct is its rust view.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub gated: bool,
    pub activation: String,
    pub rope_theta: f32,
    pub rmsnorm_eps: f32,
    pub init_std: f32,
    pub train_batch: usize,
    pub seq_len: usize,
    pub score_batch: usize,
    pub twell_tile_n: usize,
    pub twell_comp: usize,
    pub ell_width: usize,
    pub dense_backup_frac: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab_size: j.get("vocab_size")?.as_usize()?,
            d_model: j.get("d_model")?.as_usize()?,
            n_layers: j.get("n_layers")?.as_usize()?,
            n_heads: j.get("n_heads")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            gated: j.get("gated")?.as_bool()?,
            activation: j.get("activation")?.as_str()?.to_string(),
            rope_theta: j.get("rope_theta")?.as_f64()? as f32,
            rmsnorm_eps: j.get("rmsnorm_eps")?.as_f64()? as f32,
            init_std: j.get("init_std")?.as_f64()? as f32,
            train_batch: j.get("train_batch")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            score_batch: j.get("score_batch")?.as_usize()?,
            twell_tile_n: j.get("twell_tile_n")?.as_usize()?,
            twell_comp: j.get("twell_comp")?.as_usize()?,
            ell_width: j.get("ell_width")?.as_usize()?,
            dense_backup_frac: j.get("dense_backup_frac")?.as_f64()?,
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count implied by the layout (matches param_specs).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let f = self.d_ff;
        let mut n = self.vocab_size * d; // tied embedding
        let per_layer =
            2 * d + 4 * d * d + if self.gated { 3 * d * f } else { 2 * d * f };
        n += self.n_layers * per_layer;
        n + d // final norm
    }
}

/// Training-run hyperparameters owned by the rust coordinator (the ones
/// that are runtime inputs of the AOT'd train step).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub peak_lr: f64,
    pub warmup_steps: usize,
    pub l1_coeff: f64,
    pub seed: u64,
    /// dead-neuron mitigation: none | reinit | warmup (appendix C.3)
    pub mitigation: String,
    /// reinit interpolation strength lambda (eq. 6)
    pub reinit_lambda: f64,
    /// L1 warmup: steps at 0 then linear ramp over the same span
    pub l1_warmup_steps: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 400,
            peak_lr: 1e-3,
            warmup_steps: 60,
            l1_coeff: 0.0,
            seed: 0,
            mitigation: "none".into(),
            reinit_lambda: 0.1,
            l1_warmup_steps: 0,
            log_every: 20,
        }
    }
}

impl TrainConfig {
    /// Cosine schedule with linear warmup (appendix B.1).
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup_steps {
            return self.peak_lr * (step as f64 + 1.0) / self.warmup_steps as f64;
        }
        let t = (step - self.warmup_steps) as f64
            / (self.steps - self.warmup_steps).max(1) as f64;
        let t = t.min(1.0);
        0.5 * self.peak_lr * (1.0 + (std::f64::consts::PI * t).cos())
    }

    /// Effective L1 coefficient at a step (supports the warmup strategy).
    pub fn l1_at(&self, step: usize) -> f64 {
        if self.mitigation == "warmup" && self.l1_warmup_steps > 0 {
            if step < self.l1_warmup_steps {
                0.0
            } else if step < 2 * self.l1_warmup_steps {
                self.l1_coeff * (step - self.l1_warmup_steps) as f64
                    / self.l1_warmup_steps as f64
            } else {
                self.l1_coeff
            }
        } else {
            self.l1_coeff
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = TrainConfig::default();
        if let Some(v) = j.opt("steps") { c.steps = v.as_usize()?; }
        if let Some(v) = j.opt("peak_lr") { c.peak_lr = v.as_f64()?; }
        if let Some(v) = j.opt("warmup_steps") { c.warmup_steps = v.as_usize()?; }
        if let Some(v) = j.opt("l1_coeff") { c.l1_coeff = v.as_f64()?; }
        if let Some(v) = j.opt("seed") { c.seed = v.as_f64()? as u64; }
        if let Some(v) = j.opt("mitigation") { c.mitigation = v.as_str()?.to_string(); }
        if let Some(v) = j.opt("reinit_lambda") { c.reinit_lambda = v.as_f64()?; }
        if let Some(v) = j.opt("l1_warmup_steps") { c.l1_warmup_steps = v.as_usize()?; }
        if let Some(v) = j.opt("log_every") { c.log_every = v.as_usize()?; }
        Ok(c)
    }
}

/// Where artifacts / runs live.  Everything is relative to the repo root
/// unless overridden.
#[derive(Clone, Debug)]
pub struct Paths {
    pub artifacts: PathBuf,
    pub runs: PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        Paths { artifacts: PathBuf::from("artifacts"), runs: PathBuf::from("runs") }
    }
}

impl Paths {
    pub fn manifest(&self, preset: &str) -> PathBuf {
        self.artifacts.join(preset).join("manifest.json")
    }

    pub fn artifact(&self, preset: &str, file: &str) -> PathBuf {
        self.artifacts.join(preset).join(file)
    }

    pub fn run_dir(&self, run_name: &str) -> PathBuf {
        self.runs.join(run_name)
    }
}

/// Tiny CLI argument helper: `--key value` pairs plus positional args.
/// (clap is not vendored offline.)
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: Vec<(String, String)>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or bare switch
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.push((k.to_string(), v.to_string()));
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.flags.push((key.to_string(), it.next().unwrap()));
                } else {
                    out.switches.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        match self.get(key) {
            Some(v) => Ok(v),
            None => bail!("missing required flag --{key}"),
        }
    }
}

/// Load a model config from an artifact manifest on disk.
pub fn load_model_config(paths: &Paths, preset: &str) -> Result<ModelConfig> {
    let man = Json::read_file(&paths.manifest(preset))?;
    ModelConfig::from_json(man.get("config")?)
}

pub fn repo_root() -> PathBuf {
    // walk up from cwd until we find Cargo.toml (so binaries work from
    // target/release too)
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join("Cargo.toml").exists() {
            return dir;
        }
        if !dir.pop() {
            return PathBuf::from(".");
        }
    }
}

pub fn default_paths() -> Paths {
    let root = repo_root();
    Paths { artifacts: root.join("artifacts"), runs: root.join("runs") }
}

#[allow(unused)]
fn _path_helper(_: &Path) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_switches() {
        let a = Args::parse(
            ["train", "--preset", "m", "--steps=100", "--verbose"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("preset"), Some("m"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.has("verbose"));
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn lr_schedule_shape() {
        let c = TrainConfig { steps: 100, warmup_steps: 10, peak_lr: 1.0,
                              ..TrainConfig::default() };
        assert!(c.lr_at(0) < c.lr_at(9));
        assert!((c.lr_at(9) - 1.0).abs() < 0.11);
        assert!(c.lr_at(50) < 1.0);
        assert!(c.lr_at(99) < c.lr_at(50));
        assert!(c.lr_at(99) >= 0.0);
    }

    #[test]
    fn l1_warmup_schedule() {
        let c = TrainConfig {
            l1_coeff: 2.0,
            mitigation: "warmup".into(),
            l1_warmup_steps: 10,
            ..TrainConfig::default()
        };
        assert_eq!(c.l1_at(0), 0.0);
        assert_eq!(c.l1_at(9), 0.0);
        assert!((c.l1_at(15) - 1.0).abs() < 1e-9);
        assert_eq!(c.l1_at(25), 2.0);
    }

    #[test]
    fn param_count_gated_matches_formula() {
        let c = ModelConfig {
            name: "t".into(), vocab_size: 256, d_model: 64, n_layers: 2,
            n_heads: 2, d_ff: 176, gated: true, activation: "relu".into(),
            rope_theta: 1e4, rmsnorm_eps: 1e-5, init_std: 0.02,
            train_batch: 4, seq_len: 64, score_batch: 8, twell_tile_n: 16,
            twell_comp: 4, ell_width: 64, dense_backup_frac: 0.125,
        };
        let per_layer = 2 * 64 + 4 * 64 * 64 + 3 * 64 * 176;
        assert_eq!(c.param_count(), 256 * 64 + 2 * per_layer + 64);
    }

    #[test]
    fn train_config_from_json_overrides() {
        let j = Json::parse(r#"{"steps": 7, "l1_coeff": 0.5}"#).unwrap();
        let c = TrainConfig::from_json(&j).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.l1_coeff, 0.5);
        assert_eq!(c.peak_lr, 1e-3); // default preserved
    }
}
