//! Analytical device performance model (roofline) for H100 PCIe and
//! RTX PRO 6000 — the figure 12 substrate (DESIGN.md section 1).
//!
//! The paper's appendix D.4 mechanism: dense GEMMs are tensor-core bound
//! (H100 wins ~2x), bandwidth-bound conversions are slightly slower on
//! the RTX 6000 (1.59 vs 2.0 TB/s), but the *sparse* kernels are
//! CUDA-core/occupancy bound and scale with SM count (188 vs 114), so the
//! RTX 6000 runs them 1.3-2.1x faster — making the net training speedup
//! from sparsity *larger* on the cheaper device.  This module reproduces
//! that crossover from first principles.

#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub sms: u32,
    /// dense tensor-core throughput, bf16 FLOP/s
    pub tc_flops: f64,
    /// CUDA-core (vector) throughput, FLOP/s
    pub cuda_flops: f64,
    /// HBM bandwidth, bytes/s
    pub hbm_bw: f64,
    /// per-kernel-launch overhead, seconds
    pub launch_overhead: f64,
}

pub const H100_PCIE: Device = Device {
    name: "H100-PCIe",
    sms: 114,
    tc_flops: 756e12,
    cuda_flops: 51e12,
    hbm_bw: 2.0e12,
    launch_overhead: 4e-6,
};

pub const RTX6000: Device = Device {
    name: "RTX-PRO-6000",
    sms: 188,
    tc_flops: 360e12,
    cuda_flops: 110e12,
    hbm_bw: 1.59e12,
    launch_overhead: 4e-6,
};

impl Device {
    /// Roofline time for a dense tensor-core GEMM.
    pub fn dense_gemm_s(&self, flops: u64, bytes: u64) -> f64 {
        (flops as f64 / self.tc_flops)
            .max(bytes as f64 / self.hbm_bw)
            + self.launch_overhead
    }

    /// Roofline time for a CUDA-core sparse kernel.  Sparse ELL/TwELL
    /// workloads are latency/occupancy bound, not HBM bound: each
    /// single-warp CTA issues gathers whose latency must be hidden by
    /// concurrency, so the effective streaming rate scales with SM count
    /// (the paper's appendix D.4 observation — 1.34x/2.1x faster sparse
    /// ops on the SM-richer RTX 6000 despite its lower bandwidth).
    pub fn sparse_kernel_s(&self, flops: u64, bytes: u64) -> f64 {
        let gather_eff = 0.35; // irregular access discount on vector FLOPs
        let per_sm_stream = 12e9; // bytes/s of latency-hidden gather per SM
        (flops as f64 / (self.cuda_flops * gather_eff))
            .max(bytes as f64 / (self.sms as f64 * per_sm_stream))
            + self.launch_overhead
    }
}

/// Estimated time of the paper's *training-step* FFN pipeline at a given
/// sparsity (per layer, batch of `m` tokens), decomposed like app. D.4.
#[derive(Clone, Copy, Debug)]
pub struct TrainStepEstimate {
    pub dense_gemm_s: f64,
    pub conversion_s: f64,
    pub sparse_ops_s: f64,
}

impl TrainStepEstimate {
    pub fn total(&self) -> f64 {
        self.dense_gemm_s + self.conversion_s + self.sparse_ops_s
    }
}

/// Dense baseline: all three projections fwd + 2x bwd as TC GEMMs.
pub fn train_ffn_dense(dev: &Device, m: usize, k: usize, n: usize) -> f64 {
    let flops = 3 * crate::metrics::flops::ffn_gated_dense(m, k, n);
    let bytes = 3 * crate::metrics::energy::ffn_dense_bytes(m, k, n, 2);
    // 3 forward GEMMs + 6 backward GEMMs as separate launches
    dev.dense_gemm_s(flops, bytes) + 8.0 * dev.launch_overhead
}

/// Sparse hybrid-format training step (section 3.5): the gate GEMM stays
/// on tensor cores; conversion is bandwidth bound; up/down fwd + all bwd
/// matmuls touch only nnz rows on CUDA cores.
pub fn train_ffn_hybrid(
    dev: &Device, m: usize, k: usize, n: usize, avg_nnz: f64,
) -> TrainStepEstimate {
    let (mf, kf, nf) = (m as f64, k as f64, n as f64);
    let gate_flops = (2.0 * mf * kf * nf) as u64;
    let gate_bytes = ((mf * kf + kf * nf + mf * nf / 8.0) * 2.0) as u64;
    // backward also recomputes two dense GEMMs for grad wrt W_g and x
    let dense_s = 3.0 * dev.dense_gemm_s(gate_flops, gate_bytes);
    // conversion: stream the TwELL representation once
    let conv_bytes = (mf * nf / 8.0 * 4.0) as u64;
    let conv_s = dev.sparse_kernel_s((2.0 * mf * nf) as u64, conv_bytes);
    // sparse matmuls: 2 fwd (up, down) + 3 bwd, each ~ 2*k per nnz;
    // DRAM traffic counts unique weight rows only (L2 reuse, section 3.3)
    let nnz_total = mf * avg_nnz;
    let uniq = crate::metrics::energy::unique_columns(n, nnz_total as u64);
    let sp_flops = (5.0 * nnz_total * 2.0 * kf) as u64;
    let sp_bytes = 5 * uniq * (kf as u64) * 2;
    let sparse_s = dev.sparse_kernel_s(sp_flops, sp_bytes);
    TrainStepEstimate { dense_gemm_s: dense_s, conversion_s: conv_s,
                        sparse_ops_s: sparse_s }
}

/// Relative training speedup of sparse vs dense on a device (figure 12's
/// y-axis).
pub fn train_speedup(dev: &Device, m: usize, k: usize, n: usize,
                     avg_nnz: f64) -> f64 {
    train_ffn_dense(dev, m, k, n) / train_ffn_hybrid(dev, m, k, n, avg_nnz).total()
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: usize = 2048;
    const K: usize = 2048;
    const N: usize = 5632;

    #[test]
    fn dense_gemm_faster_on_h100() {
        // appendix D.4: dense GEMM ~400us on H100 vs ~800us on RTX6000
        let h = train_ffn_dense(&H100_PCIE, M, K, N);
        let r = train_ffn_dense(&RTX6000, M, K, N);
        assert!(r > 1.5 * h, "h100={h} rtx={r}");
    }

    #[test]
    fn sparse_ops_faster_on_rtx6000() {
        let h = train_ffn_hybrid(&H100_PCIE, M, K, N, 30.0);
        let r = train_ffn_hybrid(&RTX6000, M, K, N, 30.0);
        assert!(r.sparse_ops_s < h.sparse_ops_s,
                "rtx sparse {} !< h100 sparse {}", r.sparse_ops_s,
                h.sparse_ops_s);
    }

    #[test]
    fn speedup_larger_on_rtx6000() {
        // the figure 12 headline: sparsity helps the cheaper device more
        let sh = train_speedup(&H100_PCIE, M, K, N, 30.0);
        let sr = train_speedup(&RTX6000, M, K, N, 30.0);
        assert!(sr > sh, "h100 {sh} rtx {sr}");
        assert!(sh > 1.0, "sparse must still win on H100: {sh}");
    }

    #[test]
    fn speedup_decreases_with_density() {
        let lo = train_speedup(&H100_PCIE, M, K, N, 30.0);
        let hi = train_speedup(&H100_PCIE, M, K, N, 900.0);
        assert!(lo > hi, "{lo} !> {hi}");
    }
}
