//! The seven synthetic downstream tasks (paper-table-6 stand-ins).
//!
//! | task                   | paper analogue  | probes                          |
//! |------------------------|-----------------|---------------------------------|
//! | topic-match            | CQA             | topical association             |
//! | entity-recall          | OpenBookQA      | in-topic entity knowledge       |
//! | link-completion        | (fig. 7 tokens) | boilerplate continuation        |
//! | contraction-expansion  | WinoGrande-ish  | syntactic completion            |
//! | template-completion    | HellaSwag       | sentence continuation           |
//! | span-copy              | ARC-easy        | context copying                 |
//! | verb-selection         | PIQA            | subject/verb plausibility       |

use crate::data::corpus::{
    ADJECTIVES, CONTRACTIONS, DETERMINERS, NOUNS, TOPICS, VERBS,
};
use crate::eval::Instance;
use crate::util::rng::Pcg32;

pub type Generator = fn(&mut Pcg32) -> Instance;

pub fn all_tasks() -> Vec<(&'static str, Generator)> {
    vec![
        ("topic-match", topic_match),
        ("entity-recall", entity_recall),
        ("link-completion", link_completion),
        ("contraction-expansion", contraction_expansion),
        ("template-completion", template_completion),
        ("span-copy", span_copy),
        ("verb-selection", verb_selection),
    ]
}

fn pick<'a, T>(rng: &mut Pcg32, xs: &'a [T]) -> &'a T {
    &xs[rng.usize_below(xs.len())]
}

/// Given a topical sentence, choose the matching topic header.
fn topic_match(rng: &mut Pcg32) -> Instance {
    let topic = rng.usize_below(TOPICS.len());
    let noun = pick(rng, &NOUNS[topic]);
    let verb = pick(rng, &VERBS[topic]);
    let noun2 = pick(rng, &NOUNS[topic]);
    // prompt reverses the corpus order (body -> topic), probing the
    // association rather than the literal template
    let prompt = format!("the {noun} {verb} the {noun2} . topic");
    let gold_choice = format!(" {}", TOPICS[topic]);
    let mut choices = vec![gold_choice];
    for t in 0..TOPICS.len() {
        if t != topic {
            choices.push(format!(" {}", TOPICS[t]));
        }
    }
    shuffle_with_gold(rng, prompt, choices)
}

/// Complete a topical sentence with an in-topic entity vs out-of-topic
/// distractors.
fn entity_recall(rng: &mut Pcg32) -> Instance {
    let topic = rng.usize_below(TOPICS.len());
    let noun = pick(rng, &NOUNS[topic]);
    let verb = pick(rng, &VERBS[topic]);
    let prompt =
        format!("topic {} : the {noun} {verb} the", TOPICS[topic]);
    let gold = format!(" {}", pick(rng, &NOUNS[topic]));
    let mut choices = vec![gold.clone()];
    while choices.len() < 4 {
        let other_topic = rng.usize_below(TOPICS.len());
        if other_topic == topic {
            continue;
        }
        let distractor = format!(" {}", pick(rng, &NOUNS[other_topic]));
        if !choices.contains(&distractor) {
            choices.push(distractor);
        }
    }
    shuffle_with_gold(rng, prompt, choices)
}

/// The figure-7 boilerplate: "source : www nih" -> "gov".
fn link_completion(rng: &mut Pcg32) -> Instance {
    let mid = pick(rng, &["nih", "nlm", "gov"]);
    let prompt = format!("source : www {mid}");
    let choices = vec![
        " gov".to_string(),
        " valley".to_string(),
        " enzyme".to_string(),
        " treaty".to_string(),
    ];
    Instance { prompt, choices, gold: 0 }
}

/// "doesn" must continue with "'t" (contraction stems are the paper's
/// lowest-nnz tokens).
fn contraction_expansion(rng: &mut Pcg32) -> Instance {
    let stem = pick(rng, &CONTRACTIONS);
    let topic = rng.usize_below(TOPICS.len());
    let noun = pick(rng, &NOUNS[topic]);
    let prompt = format!("the {noun} {stem}");
    let choices = vec![
        " 't".to_string(),
        " the".to_string(),
        " of".to_string(),
        " gov".to_string(),
    ];
    Instance { prompt, choices, gold: 0 }
}

/// HellaSwag-style continuation: after "det adj noun verb det ..." a
/// noun is grammatical, boilerplate is not.
fn template_completion(rng: &mut Pcg32) -> Instance {
    let topic = rng.usize_below(TOPICS.len());
    let det = pick(rng, &DETERMINERS);
    let adj = pick(rng, &ADJECTIVES);
    let noun = pick(rng, &NOUNS[topic]);
    let verb = pick(rng, &VERBS[topic]);
    let det2 = pick(rng, &DETERMINERS);
    let prompt =
        format!("topic {} : {det} {adj} {noun} {verb} {det2}", TOPICS[topic]);
    let gold = format!(" {}", pick(rng, &NOUNS[topic]));
    let choices = vec![
        gold,
        " doi".to_string(),
        " :".to_string(),
        " because".to_string(),
    ];
    Instance { prompt, choices, gold: 0 }
}

/// Copy an entity mentioned earlier in the context (ARC-easy retrieval).
fn span_copy(rng: &mut Pcg32) -> Instance {
    let topic = rng.usize_below(TOPICS.len());
    let noun_idx = rng.usize_below(NOUNS[topic].len());
    let noun = NOUNS[topic][noun_idx];
    let verb = pick(rng, &VERBS[topic]);
    let prompt = format!(
        "topic {} : the {noun} {verb} the {noun} . the {noun} {verb} the",
        TOPICS[topic]
    );
    let gold = format!(" {noun}");
    let mut choices = vec![gold.clone()];
    for cand in NOUNS[topic] {
        if choices.len() >= 4 {
            break;
        }
        let c = format!(" {cand}");
        if !choices.contains(&c) {
            choices.push(c);
        }
    }
    shuffle_with_gold(rng, prompt, choices)
}

/// Choose the verb that matches the sentence's topic (PIQA-ish
/// plausibility).
fn verb_selection(rng: &mut Pcg32) -> Instance {
    let topic = rng.usize_below(TOPICS.len());
    let other = (topic + 1 + rng.usize_below(TOPICS.len() - 1))
        % TOPICS.len();
    let noun = pick(rng, &NOUNS[topic]);
    let prompt = format!("topic {} : the {noun}", TOPICS[topic]);
    let gold = format!(" {}", pick(rng, &VERBS[topic]));
    let mut choices = vec![gold.clone()];
    for cand in VERBS[other] {
        if choices.len() >= 4 {
            break;
        }
        let c = format!(" {cand}");
        if !choices.contains(&c) {
            choices.push(c);
        }
    }
    shuffle_with_gold(rng, prompt, choices)
}

/// Shuffle choices (gold currently first) and return with updated index.
fn shuffle_with_gold(rng: &mut Pcg32, prompt: String, choices: Vec<String>)
    -> Instance {
    let gold_text = choices[0].clone();
    let mut shuffled = choices;
    rng.shuffle(&mut shuffled);
    let gold = shuffled.iter().position(|c| *c == gold_text).unwrap();
    Instance { prompt, choices: shuffled, gold }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_completion_gold_is_gov() {
        let mut rng = Pcg32::seeded(1);
        let inst = link_completion(&mut rng);
        assert_eq!(inst.choices[inst.gold], " gov");
    }

    #[test]
    fn entity_recall_distractors_off_topic() {
        let mut rng = Pcg32::seeded(2);
        for _ in 0..50 {
            let inst = entity_recall(&mut rng);
            // topic is named in the prompt; gold noun belongs to it
            let topic = TOPICS
                .iter()
                .position(|t| inst.prompt.contains(t))
                .unwrap();
            let gold = inst.choices[inst.gold].trim();
            assert!(NOUNS[topic].contains(&gold), "{gold} vs {topic}");
        }
    }

    #[test]
    fn span_copy_gold_appears_in_prompt() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..20 {
            let inst = span_copy(&mut rng);
            assert!(inst.prompt.contains(inst.choices[inst.gold].trim()));
        }
    }
}
