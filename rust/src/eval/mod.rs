//! Downstream evaluation harness — the seven-task stand-in for the
//! paper's HellaSwag/PIQA/ARC/OBQA/WinoGrande/CQA suite (DESIGN.md
//! section 2).
//!
//! Each task is a generator of cloze-style multiple-choice instances over
//! the synthetic grammar; scoring follows the standard protocol: the
//! model scores `prompt + choice_i` and the length-normalized choice
//! log-prob decides the prediction.  Tasks are constructed so that a
//! model that learned the corpus regularities beats chance, and a
//! capability regression under aggressive sparsity shows up exactly as in
//! the paper's figure 3.

pub mod tasks;

use anyhow::Result;

use crate::data::bpe::Bpe;
use crate::model::Model;
use crate::util::rng::Pcg32;

#[derive(Clone, Debug)]
pub struct Instance {
    pub prompt: String,
    pub choices: Vec<String>,
    pub gold: usize,
}

pub struct TaskResult {
    pub task: String,
    pub accuracy: f64,
    pub n: usize,
}

/// Score one instance with length-normalized cloze log-prob.
fn classify(model: &Model, bpe: &Bpe, inst: &Instance) -> usize {
    let prompt_ids = bpe.encode(&inst.prompt);
    let mut best = (f64::NEG_INFINITY, 0usize);
    for (ci, choice) in inst.choices.iter().enumerate() {
        let choice_ids = bpe.encode(choice);
        if choice_ids.is_empty() {
            continue;
        }
        let mut seq = prompt_ids.clone();
        seq.extend(&choice_ids);
        let logp = model.score(&seq, 1, seq.len());
        // positions prompt_len-1 .. end-1 predict the choice tokens
        let start = prompt_ids.len() - 1;
        let total: f64 = logp[start..].iter().map(|&v| v as f64).sum();
        let norm = total / choice_ids.len() as f64;
        if norm > best.0 {
            best = (norm, ci);
        }
    }
    best.1
}

/// Run every task; returns per-task accuracies (Table 6 row) in a fixed
/// order.
pub fn evaluate(model: &Model, bpe: &Bpe, n_per_task: usize, seed: u64)
    -> Result<Vec<TaskResult>> {
    let mut results = Vec::new();
    for (name, gen) in tasks::all_tasks() {
        let mut rng = Pcg32::seeded(seed ^ hash_name(name));
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_per_task {
            let inst = gen(&mut rng);
            if classify(model, bpe, &inst) == inst.gold {
                correct += 1;
            }
            total += 1;
        }
        results.push(TaskResult {
            task: name.to_string(),
            accuracy: correct as f64 / total as f64,
            n: total,
        });
    }
    Ok(results)
}

pub fn mean_accuracy(results: &[TaskResult]) -> f64 {
    crate::util::stats::mean(
        &results.iter().map(|r| r.accuracy).collect::<Vec<_>>(),
    )
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instances_have_valid_gold() {
        let mut rng = Pcg32::seeded(0);
        for (name, gen) in tasks::all_tasks() {
            for _ in 0..20 {
                let inst = gen(&mut rng);
                assert!(inst.gold < inst.choices.len(), "{name}");
                assert!(inst.choices.len() >= 2, "{name}");
                assert!(!inst.prompt.is_empty(), "{name}");
                // gold choice text must differ from every distractor
                let gold = &inst.choices[inst.gold];
                for (i, c) in inst.choices.iter().enumerate() {
                    if i != inst.gold {
                        assert_ne!(c, gold, "{name}: duplicate choice");
                    }
                }
            }
        }
    }

    #[test]
    fn seven_tasks_like_the_paper() {
        assert_eq!(tasks::all_tasks().len(), 7);
    }

    #[test]
    fn generators_deterministic_per_seed() {
        for (_, gen) in tasks::all_tasks() {
            let mut a = Pcg32::seeded(5);
            let mut b = Pcg32::seeded(5);
            let ia = gen(&mut a);
            let ib = gen(&mut b);
            assert_eq!(ia.prompt, ib.prompt);
            assert_eq!(ia.choices, ib.choices);
        }
    }
}
