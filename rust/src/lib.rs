//! Sparser, Faster, Lighter Transformer Language Models — reproduction.
//!
//! Three-layer architecture (DESIGN.md): this crate is Layer 3, the rust
//! coordinator; `python/compile/` is the build-time L2 (JAX model) and L1
//! (Pallas kernels), AOT-lowered to `artifacts/*.hlo.txt` which
//! `runtime/` executes via PJRT.  `sparse/` holds the paper's kernel
//! algorithms (TwELL, fused inference, hybrid training) as CPU kernels.

// Every `unsafe fn` must spell out its internal unsafe operations in
// explicit blocks (each carrying a `// SAFETY:` justification — the
// xtask lint gate checks that part).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod config;
pub mod data;
pub mod metrics;
pub mod coordinator;
pub mod model;
pub mod perfmodel;
pub mod eval;
pub mod runtime;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod util;
