//! Row-major f32 matrix used throughout the sparse kernels and the rust
//! inference engine.  Deliberately minimal: the heavy lifting lives in the
//! kernels (`sparse/`) which operate on raw slices for performance.

use crate::util::rng::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg32) -> Self {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Logically resize to `rows`, keeping `cols`.  The backing `Vec`
    /// only reallocates when growing past its high-water mark, so a
    /// scratch matrix sized once at its maximum is reshaped for free —
    /// the decode hot loop relies on this being allocation-free.
    pub fn set_rows(&mut self, rows: usize) {
        self.rows = rows;
        self.data.resize(rows * self.cols, 0.0);
    }

    /// Logically resize both dimensions (the routed FFN's gathered-up
    /// activation buffer changes width every decode step).  Same
    /// high-water contract as `set_rows`: no reallocation once the
    /// backing `Vec` has seen its maximum size.
    pub fn set_shape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Column-concatenate matrices with equal row counts:
    /// `[A | B | ...]`.  Used to pre-fuse the Q/K/V projection weights
    /// into one `(d, 3d)` matrix at model load.
    pub fn hcat(parts: &[&Mat]) -> Mat {
        assert!(!parts.is_empty());
        let rows = parts[0].rows;
        assert!(parts.iter().all(|p| p.rows == rows), "row mismatch");
        let cols = parts.iter().map(|p| p.cols).sum();
        let mut out = Mat::zeros(rows, cols);
        for r in 0..rows {
            let orow = out.row_mut(r);
            let mut at = 0;
            for p in parts {
                orow[at..at + p.cols].copy_from_slice(p.row(r));
                at += p.cols;
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Count of strictly positive entries (the paper's nnz statistic).
    pub fn nnz_positive(&self) -> usize {
        self.data.iter().filter(|&&x| x > 0.0).count()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative Frobenius error ||a-b|| / max(||b||, eps).
    pub fn rel_err(&self, other: &Mat) -> f32 {
        let mut num = 0f64;
        let mut den = 0f64;
        for (a, b) in self.data.iter().zip(&other.data) {
            num += ((a - b) * (a - b)) as f64;
            den += (b * b) as f64;
        }
        (num.sqrt() / den.sqrt().max(1e-12)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.at(0, 2), 3.0);
        assert_eq!(m.at(1, 0), 4.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg32::seeded(1);
        let m = Mat::randn(5, 7, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn nnz_counts_positive_only() {
        let m = Mat::from_vec(1, 4, vec![1.0, -1.0, 0.0, 0.5]);
        assert_eq!(m.nnz_positive(), 2);
    }

    #[test]
    fn hcat_concatenates_columns() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![5., 6.]);
        let c = Mat::hcat(&[&a, &b]);
        assert_eq!((c.rows, c.cols), (2, 3));
        assert_eq!(c.data, vec![1., 2., 5., 3., 4., 6.]);
    }

    #[test]
    fn set_rows_reshapes_without_losing_width() {
        let mut m = Mat::zeros(4, 3);
        let cap = m.data.capacity();
        m.set_rows(2);
        assert_eq!((m.rows, m.data.len()), (2, 6));
        m.set_rows(4);
        assert_eq!((m.rows, m.data.len()), (4, 12));
        assert_eq!(m.data.capacity(), cap, "scratch reshape reallocated");
    }

    #[test]
    fn set_shape_reshapes_both_dims_without_reallocating() {
        let mut m = Mat::zeros(4, 8);
        let cap = m.data.capacity();
        m.set_shape(2, 5);
        assert_eq!((m.rows, m.cols, m.data.len()), (2, 5, 10));
        m.set_shape(4, 8);
        assert_eq!((m.rows, m.cols, m.data.len()), (4, 8, 32));
        assert_eq!(m.data.capacity(), cap, "scratch reshape reallocated");
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let m = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        assert_eq!(m.rel_err(&m), 0.0);
    }
}
