//! EXP-F5 + table 1 training column: hybrid-format training step vs the
//! dense baseline — wall-clock speedup and peak activation memory across
//! sparsity levels (paper figure 5: up to ~24% faster and >24% less peak
//! memory, growing with sparsity).

use repro::metrics::memory;
use repro::sparse::ffn::{
    synth_sparse_ffn, train_step_dense, train_step_hybrid,
};
use repro::tensor::Mat;
use repro::util::bench::{Bencher, Table};
use repro::util::rng::Pcg32;

fn main() {
    let (m, k, n) = (256, 256, 704); // paper dims / 8
    println!("== figure 5 / table 1 (training): hybrid training step ==");
    println!("dims: M={m} K={k} N={n}, ELL width 128, tail M/8\n");

    let mut table = Table::new(&[
        "avg nnz", "dense tok/ms", "hybrid tok/ms", "speedup",
        "dense peak B", "hybrid peak B", "mem delta", "overflow",
    ]);
    let bencher = Bencher::quick();
    let mut rng = Pcg32::seeded(3);
    let dy = Mat::randn(m, k, 1.0, &mut rng);
    for target_nnz in [660.0, 352.0, 113.0, 30.0, 8.0] {
        let comp = if target_nnz > 176.0 { 1 } else { 4 };
        let (w, x) = synth_sparse_ffn(m, k, n, target_nnz, 11, 32, comp,
                                      128, 0.125);
        let gd = train_step_dense(&w, &x, &dy, 0.01);
        let gh = train_step_hybrid(&w, &x, &dy, 0.01);
        let rd = bencher.run("dense", || {
            std::hint::black_box(
                train_step_dense(&w, &x, &dy, 0.01).dwd.data[0],
            );
        });
        let rh = bencher.run("hybrid", || {
            std::hint::black_box(
                train_step_hybrid(&w, &x, &dy, 0.01).dwd.data[0],
            );
        });
        table.row(&[
            format!("{:.1}", gh.nnz as f64 / m as f64),
            format!("{:.2}", m as f64 / (rd.median_s * 1e3)),
            format!("{:.2}", m as f64 / (rh.median_s * 1e3)),
            format!("{:+.1}%", 100.0 * (rd.median_s / rh.median_s - 1.0)),
            gd.peak_activation_bytes.to_string(),
            gh.peak_activation_bytes.to_string(),
            format!(
                "{:+.1}%",
                100.0
                    * (gh.peak_activation_bytes as f64
                        / gd.peak_activation_bytes as f64
                        - 1.0)
            ),
            gh.overflow.to_string(),
        ]);
    }
    table.print();

    // appendix B.2.1 sizing ablation: ELL width / dense-tail trade-off
    println!("\n== appendix B.2.1 ablation: hybrid structure sizing ==");
    let mut t2 = Table::new(&[
        "ell width", "tail frac", "hybrid tok/ms", "peak B", "overflow",
    ]);
    let (_, x) = synth_sparse_ffn(m, k, n, 30.0, 11, 32, 4, 128, 0.125);
    for (width, tail) in
        [(32, 0.03125), (64, 0.0625), (128, 0.125), (256, 0.25)]
    {
        let (w, _) = synth_sparse_ffn(m, k, n, 30.0, 11, 32, 4, width, tail);
        let g = train_step_hybrid(&w, &x, &dy, 0.01);
        let r = bencher.run("hybrid", || {
            std::hint::black_box(
                train_step_hybrid(&w, &x, &dy, 0.01).dwd.data[0],
            );
        });
        t2.row(&[
            width.to_string(),
            format!("{tail}"),
            format!("{:.2}", m as f64 / (r.median_s * 1e3)),
            g.peak_activation_bytes.to_string(),
            g.overflow.to_string(),
        ]);
    }
    t2.print();
    println!(
        "\nshape check vs paper fig. 5 + B.2.1: speedup and memory \
         savings grow with sparsity; width 128 + tail M/8 is safe, \
         tighter structures save memory until overflow flags fire."
    );
    let _ = memory::dense_bytes(1, 1, 4);
}
