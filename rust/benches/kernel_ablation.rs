//! Design-choice ablations called out in DESIGN.md / appendix A:
//!   * TwELL tile width T_n and compression factor C (storage vs overflow
//!     vs pack cost),
//!   * fused up+down (algorithm 2) vs two separate sparse kernels,
//!   * ELL baseline SpMM vs hybrid-routed matmul under heavy-row skew
//!     (the pathology that motivates the hybrid format, section 3.4).

use repro::sparse::dense;
use repro::sparse::ell::EllMatrix;
use repro::sparse::ffn::synth_sparse_ffn;
use repro::sparse::fused::{down_from_twell, fused_up_down};
use repro::sparse::hybrid::HybridMatrix;
use repro::sparse::twell::gate_matmul_twell;
use repro::tensor::Mat;
use repro::util::bench::{fmt_time, Bencher, Table};
use repro::util::rng::Pcg32;

fn main() {
    let (m, k, n) = (256, 256, 704);
    let bencher = Bencher::quick();

    println!("== ablation 1: TwELL tile width / compression ==");
    let mut t1 = Table::new(&[
        "tile_n", "comp", "pack time", "bytes", "overflow",
    ]);
    for (tile_n, comp) in
        [(16, 1), (16, 4), (32, 1), (32, 4), (32, 8), (64, 4), (64, 8)]
    {
        let (w, x) =
            synth_sparse_ffn(m, k, n, 30.0, 21, tile_n, comp, 128, 0.125);
        let r = bencher.run("pack", || {
            std::hint::black_box(
                gate_matmul_twell(&x, &w.wg, tile_n, comp).total_nnz(),
            );
        });
        let tw = gate_matmul_twell(&x, &w.wg, tile_n, comp);
        t1.row(&[
            tile_n.to_string(),
            comp.to_string(),
            fmt_time(r.median_s),
            tw.bytes().to_string(),
            tw.overflow.to_string(),
        ]);
    }
    t1.print();

    println!("\n== ablation 2: fused (alg. 2) vs unfused up+down ==");
    let mut t2 = Table::new(&["avg nnz", "fused", "unfused", "fusion gain"]);
    for target in [113.0, 30.0, 8.0] {
        let (w, x) = synth_sparse_ffn(m, k, n, target, 22, 32, 4, 128, 0.125);
        let hg = gate_matmul_twell(&x, &w.wg, 32, 4);
        let rf = bencher.run("fused", || {
            std::hint::black_box(
                fused_up_down(&x, &hg, &w.wu_t, &w.wd).data[0],
            );
        });
        // unfused: materialize h via a sparse down-style pass over W_u,
        // then a second sparse pass over W_d (two kernels, h in DRAM)
        let ru = bencher.run("unfused", || {
            let mut h = hg.clone();
            let pc = h.packed_cols();
            let slots = h.slots();
            let n_tiles = h.n_tiles();
            for r in 0..h.m {
                for t in 0..n_tiles {
                    let z = h.nnz[r * n_tiles + t] as usize;
                    for c in 0..z {
                        let j = r * pc + t * slots + c;
                        let col = h.indices[j] as usize;
                        let u = dense::dot(
                            &x.data[r * k..(r + 1) * k],
                            w.wu_t.row(col),
                        );
                        h.values[j] *= u;
                    }
                }
            }
            std::hint::black_box(down_from_twell(&h, &w.wd).data[0]);
        });
        t2.row(&[
            format!("{:.1}", hg.avg_nnz_per_row()),
            fmt_time(rf.median_s),
            fmt_time(ru.median_s),
            format!("{:+.1}%", 100.0 * (ru.median_s / rf.median_s - 1.0)),
        ]);
    }
    t2.print();

    println!("\n== ablation 3: ELL vs hybrid under heavy-row skew ==");
    // sparse matrix with a few near-dense rows: classic ELL pads all rows
    // to the max (section 3.4's motivation)
    let mut rng = Pcg32::seeded(9);
    let w2 = Mat::randn(n, k, 0.3, &mut rng);
    let mut t3 = Table::new(&[
        "heavy rows", "ELL width", "ELL bytes", "hybrid bytes",
        "ELL matmul", "hybrid matmul",
    ]);
    for heavy in [0usize, 2, 8, 32] {
        let mut h = Mat::zeros(m, n);
        for r in 0..m {
            for c in 0..n {
                if rng.f32() < 30.0 / n as f32 {
                    h.data[r * n + c] = rng.f32() + 0.01;
                }
            }
        }
        for r in 0..heavy {
            for c in 0..(n * 9 / 10) {
                h.data[(r * 7 % m) * n + c] = rng.f32() + 0.01;
            }
        }
        let ell = EllMatrix::from_dense(&h);
        let hyb = HybridMatrix::from_dense(&h, 128, m / 8);
        let re = bencher.run("ell", || {
            std::hint::black_box(ell.matmul(&w2).data[0]);
        });
        let rh = bencher.run("hybrid", || {
            std::hint::black_box(hyb.matmul(&w2).data[0]);
        });
        t3.row(&[
            heavy.to_string(),
            ell.width.to_string(),
            ell.bytes().to_string(),
            hyb.bytes().to_string(),
            fmt_time(re.median_s),
            fmt_time(rh.median_s),
        ]);
    }
    t3.print();
    println!(
        "\nshape check: a handful of heavy rows blows up ELL storage \
         (global-max padding) while the hybrid format's bytes stay flat — \
         exactly the section-3.4 argument."
    );
}
