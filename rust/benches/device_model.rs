//! EXP-F12 (figure 12 / appendix D.4): device comparison via the roofline
//! perf model — H100 PCIe vs RTX PRO 6000 training speedups across the L1
//! grid — plus a CPU thread-count sensitivity check (the measurable
//! analogue of "more SMs help sparse kernels more").

use repro::perfmodel::{train_ffn_dense, train_ffn_hybrid, train_speedup,
                       Device, H100_PCIE, RTX6000};
use repro::util::bench::Table;

fn main() {
    // the paper's actual H100 dims — the model is analytical, so no need
    // to scale down
    let (m, k, n) = (2048, 2048, 5632);
    println!("== figure 12: sparse training speedup by device ==");
    println!("dims: M={m} K={k} N={n} (paper dims), roofline model\n");

    let mut table = Table::new(&[
        "avg nnz", "H100 speedup", "RTX6000 speedup", "ratio",
    ]);
    // figure 3's nnz ladder across the L1 grid
    for avg_nnz in [911.0, 400.0, 120.0, 39.0, 29.0, 8.0, 1.0] {
        let sh = train_speedup(&H100_PCIE, m, k, n, avg_nnz);
        let sr = train_speedup(&RTX6000, m, k, n, avg_nnz);
        table.row(&[
            format!("{avg_nnz:.0}"),
            format!("{sh:.2}x"),
            format!("{sr:.2}x"),
            format!("{:.2}", sr / sh),
        ]);
    }
    table.print();

    println!("\n== appendix D.4 decomposition at nnz=30 ==");
    let mut t2 = Table::new(&[
        "device", "dense GEMM", "conversion", "sparse ops", "total",
        "dense baseline",
    ]);
    for dev in [&H100_PCIE, &RTX6000] {
        let e = train_ffn_hybrid(dev, m, k, n, 30.0);
        t2.row(&[
            dev.name.to_string(),
            format!("{:.0} µs", e.dense_gemm_s * 1e6),
            format!("{:.0} µs", e.conversion_s * 1e6),
            format!("{:.0} µs", e.sparse_ops_s * 1e6),
            format!("{:.0} µs", e.total() * 1e6),
            format!("{:.0} µs", train_ffn_dense(dev, m, k, n) * 1e6),
        ]);
    }
    t2.print();

    // CPU-measurable analogue: a hypothetical device with more "SMs"
    // (issue slots) gains more from the sparse path
    println!("\n== SM-count sensitivity (mechanism check) ==");
    let mut t3 = Table::new(&["SMs", "speedup @ nnz=30"]);
    for sms in [60u32, 114, 188, 300] {
        let dev = Device { name: "synthetic", sms, ..H100_PCIE };
        t3.row(&[
            sms.to_string(),
            format!("{:.2}x", train_speedup(&dev, m, k, n, 30.0)),
        ]);
    }
    t3.print();
    println!(
        "\nshape check vs paper fig. 12 / D.4: dense GEMMs ~2x slower on \
         the RTX 6000, sparse ops faster (SM-bound), so the *relative* \
         speedup from sparsity is larger on the cheaper device, \
         increasingly so at higher sparsity."
    );
}
