//! EXP-F4 + table 1 forward column: sparse inference speedup + energy.
//!
//! Times the dense 3-GEMM gated FFN against the paper's two-kernel TwELL
//! pipeline across the sparsity levels the L1 grid induces (paper:
//! ~911 nnz unregularized down to <1), and reports the analytical energy
//! model's mJ/token alongside (the nvidia-smi stand-in, DESIGN.md).
//!
//! Expected shape (figure 4): speedup ~1x (or below) for the non-sparse
//! model, growing monotonically with sparsity; energy savings slightly
//! exceed the throughput gain.

use repro::metrics::{energy, flops};
use repro::sparse::ffn::{forward_dense, forward_twell, synth_sparse_ffn};
use repro::util::bench::{Bencher, Table};

fn main() {
    let (m, k, n) = (256, 256, 704); // paper dims / 8
    let tile_n = 32;
    println!("== figure 4 / table 1 (forward): TwELL inference pipeline ==");
    println!("dims: M={m} K={k} N={n} (paper dims / 8), f32, 1 core\n");

    let mut table = Table::new(&[
        "avg nnz", "sparsity", "dense tok/ms", "twell tok/ms", "speedup",
        "dense mJ/tok", "twell mJ/tok", "energy delta",
    ]);
    let bencher = Bencher::quick();
    // paper figure 3 range: 911 (L1=0) -> ~1; scaled to N=704: ~660 -> 1
    for target_nnz in [660.0, 352.0, 113.0, 30.0, 8.0, 1.0] {
        let comp = if target_nnz > 176.0 { 1 } else { 4 };
        let (w, x) = synth_sparse_ffn(m, k, n, target_nnz, 7, tile_n, comp,
                                      128, 0.125);
        let rd = bencher.run("dense", || {
            std::hint::black_box(forward_dense(&w, &x).data[0]);
        });
        let mut nnz_total = 0u64;
        let rs = bencher.run("twell", || {
            let (y, hg) = forward_twell(&w, &x);
            nnz_total = hg.total_nnz();
            std::hint::black_box(y.data[0]);
        });
        let avg_nnz = nnz_total as f64 / m as f64;
        // energy model (H100 constants; relative numbers are the claim)
        let dev = energy::H100_PCIE;
        let ed = dev.mj_per_token(
            flops::ffn_gated_dense(m, k, n),
            energy::ffn_dense_bytes(m, k, n, 4),
            rd.median_s,
            m as u64,
        );
        let es = dev.mj_per_token(
            flops::ffn_gated_twell(m, k, n, nnz_total),
            energy::ffn_twell_bytes(m, k, n, comp, nnz_total, 4),
            rs.median_s,
            m as u64,
        );
        table.row(&[
            format!("{avg_nnz:.1}"),
            format!("{:.1}%", 100.0 * (1.0 - avg_nnz / n as f64)),
            format!("{:.1}", m as f64 / (rd.median_s * 1e3)),
            format!("{:.1}", m as f64 / (rs.median_s * 1e3)),
            format!("{:+.1}%", 100.0 * (rd.median_s / rs.median_s - 1.0)),
            format!("{ed:.3}"),
            format!("{es:.3}"),
            format!("{:+.1}%", 100.0 * (es / ed - 1.0)),
        ]);
    }
    table.print();
    println!(
        "\nshape check vs paper fig. 4: near-dense models gain nothing \
         (or lose), speedups grow with sparsity and saturate once the \
         gate GEMM dominates; energy savings track and slightly exceed \
         the throughput gain."
    );
}
