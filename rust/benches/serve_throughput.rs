//! Continuous-batching serving throughput: tokens/sec and p50/p95
//! request latency vs KV slot count (1/4/8/16), for both FFN backends,
//! plus a time-to-first-token sweep over the prefill chunk size on
//! long prompts (4x the KV block), a sampled-decode sweep (greedy
//! argmax vs temperature 0.8 / top-p 0.95 per-request sampling), and a
//! **skinny-batch decode kernel sweep**: the seed's row-parallel
//! dispatch (which collapses every decode-shaped kernel onto one core)
//! vs the pooled column-parallel fast path, at pure-decode batch sizes
//! 1/4/8/16, plus a **decode routing sweep** (`section=decode_routing`):
//! the batch-contextual union-gathered routed FFN vs the unrouted
//! twell row path vs the dense backend at ~99% sparsity, batch 1..64,
//! with the measured batch-union column density and the dominant
//! dispatch label on every row, a **shard sweep**
//! (`section=shard_sweep`): 1/2/4 engine shards pulling from one
//! admission queue, the total worker-pool budget split evenly across
//! shards, and a **prefix-cache sweep** (`section=prefix_cache`):
//! a trace where 80% of requests share a long system prompt, served
//! with copy-on-write prefix caching on vs off — same streams, fewer
//! blocks, collapsed TTFT.
//!
//! Claims under test: decode throughput grows with the number of slots
//! because the batched step hands the FFN backends a multi-row
//! activation matrix; block-granular chunked prefill collapses TTFT on
//! long prompts; and the column-parallel fast path beats the seed
//! dispatch at **every** batch ≤ 16, because the seed path ran those
//! kernels sequentially while the pool keeps all cores fed.
//!
//! Prints the usual paper-style tables plus one machine-readable JSON
//! line (`{"bench": "serve_throughput", "rows": [...]}`), and persists
//! the same report to `BENCH_serve_throughput.json` at the repo root
//! so the perf trajectory populates across PRs.  Every row records the
//! worker-pool thread count.
//!
//! Args (after `--`): `--smoke` shrinks every wave to CI-smoke sizes
//! (same sections, same JSON schema, seconds instead of minutes);
//! `--threads N` pins the worker pool before first use.

use std::time::{Duration, Instant};

use repro::config::ModelConfig;
use repro::model::kv::{argmax, kv_positions_needed, DecodeScratch,
                       PagedKvCache};
use repro::model::sample::SamplingParams;
use repro::model::{FfnBackend, Layer, Model};
use repro::serve::{EngineStats, FinishReason, ServeMetrics, ServeMode,
                   ServePolicy, Server, SubmitError, SubmitOptions};
use repro::sparse::ffn::synth_sparse_ffn;
use repro::sparse::par;
use repro::sparse::route::RouteStats;
use repro::tensor::Mat;
use repro::util::bench::Table;
use repro::util::json::Json;
use repro::util::rng::Pcg32;

fn synthetic_model(layers: usize, target_nnz: f64, backend: FfnBackend)
    -> Model {
    let d = 128;
    let f = 352;
    let cfg = ModelConfig {
        name: format!("synth{layers}"),
        vocab_size: 512,
        d_model: d,
        n_layers: layers,
        n_heads: 4,
        d_ff: f,
        gated: true,
        activation: "relu".into(),
        rope_theta: 1e4,
        rmsnorm_eps: 1e-5,
        init_std: 0.02,
        train_batch: 16,
        seq_len: 128,
        score_batch: 32,
        twell_tile_n: 32,
        twell_comp: 4,
        ell_width: 128,
        dense_backup_frac: 0.125,
    };
    let mut rng = Pcg32::seeded(5);
    let layers_v = (0..layers)
        .map(|li| {
            let (ffn, _) = synth_sparse_ffn(
                64, d, f, target_nnz, 100 + li as u64, 32, 4, 128, 0.125,
            );
            Layer::new(
                vec![1.0; d],
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                vec![1.0; d],
                ffn,
            )
        })
        .collect();
    let embed = Mat::randn(cfg.vocab_size, d, 0.05, &mut rng);
    Model::assemble(cfg, embed, layers_v, vec![1.0; d], backend, 4)
}

/// One serving wave; returns (tok/s, p50 ms, p95 ms, TTFT p50 ms,
/// merged engine stats).  Request i samples with seed
/// `params.seed + i`, so a sampled wave exercises genuinely divergent
/// decode traffic while staying reproducible run to run.  `shards`
/// engine shards pull from one admission queue; `slots`/`kv_blocks`
/// are per shard, so capacity scales with the shard count here (the
/// shard sweep measures placement overhead, not admission pressure).
fn run_wave(backend: FfnBackend, shards: usize, slots: usize,
            n_requests: usize, prompt_len: usize, max_new: usize,
            kv_block_size: usize, prefill_chunk: usize,
            params: SamplingParams)
    -> (f64, f64, f64, f64, EngineStats) {
    let model = synthetic_model(4, 30.0, backend);
    let vocab = model.cfg.vocab_size;
    // paged KV pool sized so every slot can hold one request's worst
    // case at once (the bench measures batching, not memory pressure)
    let kv_blocks = slots
        * kv_positions_needed(prompt_len, max_new).div_ceil(kv_block_size);
    let server = Server::start(model, ServePolicy {
        slots,
        max_wait: Duration::from_millis(2),
        kv_block_size,
        kv_blocks,
        prefill_chunk,
        route_density: 0.25,
        // the prompts here are all distinct: sharing would never
        // engage, so keep it off and the historical sections exactly
        // comparable across PRs (the prefix_cache section measures it)
        prefix_cache: false,
        max_queue: 0,
        mode: ServeMode::Continuous,
        shards,
    });
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            // varied prompts so slot retirement staggers
            let prompt: Vec<u32> = (0..prompt_len)
                .map(|j| ((i * 131 + j * 31) % vocab) as u32)
                .collect();
            let req_params = SamplingParams {
                seed: params.seed.wrapping_add(i as u64),
                ..params
            };
            server
                .submit_sampled(prompt, max_new, req_params)
                .expect("request fits pool")
                .1
        })
        .collect();
    let mut metrics = ServeMetrics::default();
    for rx in rxs {
        metrics.record(rx.recv().expect("worker dropped"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let out = (
        metrics.throughput_tok_s(wall),
        metrics.p50_ms(),
        metrics.p95_ms(),
        metrics.p50_first_token_ms(),
        stats,
    );
    server.shutdown();
    out
}

/// One shared-prefix serving trace: 80% of the requests open with the
/// same system prompt (20% are unique cold prompts of equal length),
/// and sharer tails cycle over four variants so some requests are
/// exact repeats — full prefix hits that exercise the copy-on-write
/// path.  One untimed warm-up request seeds the cache first (a hot
/// prefix in steady state, not a cold start), then the timed wave.
/// Returns (tok/s, p50 ms, TTFT p50 ms, merged stats, token streams
/// in submission order) — greedy decode, so the streams must be
/// bit-identical with `prefix_cache` on and off.
fn run_prefix_wave(
    prefix_cache: bool, n_requests: usize, prefix_len: usize,
    tail_len: usize, max_new: usize, slots: usize,
) -> (f64, f64, f64, EngineStats, Vec<Vec<u32>>) {
    let model = synthetic_model(4, 30.0, FfnBackend::Twell);
    let vocab = model.cfg.vocab_size;
    let kv_block_size = 16usize;
    let prompt_len = prefix_len + tail_len;
    // sized for the sharing-off worst case, so on vs off runs the
    // identical admission budget and only the footprint differs
    let kv_blocks = slots
        * kv_positions_needed(prompt_len, max_new).div_ceil(kv_block_size);
    let server = Server::start(model, ServePolicy {
        slots,
        max_wait: Duration::from_millis(2),
        kv_block_size,
        kv_blocks,
        prefill_chunk: kv_block_size,
        route_density: 0.25,
        prefix_cache,
        max_queue: 0,
        mode: ServeMode::Continuous,
        shards: 1,
    });
    let system: Vec<u32> =
        (0..prefix_len).map(|j| ((j * 31 + 7) % vocab) as u32).collect();
    let prompt_for = |i: usize| -> Vec<u32> {
        if i % 5 == 0 {
            // 20%: a unique cold prompt of the same length
            (0..prompt_len)
                .map(|j| ((i * 977 + j * 53 + 13) % vocab) as u32)
                .collect()
        } else {
            // 80%: the shared system prompt + a short cycling tail
            let v = i % 4;
            system
                .iter()
                .copied()
                .chain((0..tail_len).map(|j| {
                    ((v * 131 + j * 31 + 1) % vocab) as u32
                }))
                .collect()
        }
    };
    let (_, warm_rx) =
        server.submit(prompt_for(1), max_new).expect("warm-up fits");
    warm_rx.recv().expect("worker dropped");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|i| {
            server
                .submit(prompt_for(i), max_new)
                .expect("request fits pool")
                .1
        })
        .collect();
    let mut metrics = ServeMetrics::default();
    let mut streams = Vec::new();
    for rx in rxs {
        let c = rx.recv().expect("worker dropped");
        streams.push(c.tokens.clone());
        metrics.record(c);
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let out = (
        metrics.throughput_tok_s(wall),
        metrics.p50_ms(),
        metrics.p50_first_token_ms(),
        stats,
        streams,
    );
    server.shutdown();
    out
}

/// One overload wave: a burst far above the 2-slot engine's capacity,
/// with or without the QoS layer.  Shedding on means a bounded queue
/// (`max_queue = slots`), a 2 ms cap on how long each submit waits for
/// queue space, and a per-request deadline of `deadline_ms` from
/// submit — except every 4th request, which arrives with its deadline
/// already spent (a client that gave up), so the admission scan's
/// deadline shedding provably engages.  Shedding off is the historical
/// behaviour: unbounded queue, no deadlines, everyone waits.
///
/// Returns (goodput tok/s, p99 TTFT ms over served requests, merged
/// stats, served count).  *Goodput* counts only tokens from requests
/// that ran to completion within the `deadline_ms` budget — the
/// shed-off run is judged against the same budget it ignored, which is
/// exactly the comparison: under overload, serving everyone late is
/// worth less than serving fewer on time.
fn run_overload_wave(
    shed: bool, n_requests: usize, prompt_len: usize, max_new: usize,
    deadline_ms: f64,
) -> (f64, f64, EngineStats, usize) {
    let model = synthetic_model(4, 30.0, FfnBackend::Twell);
    let vocab = model.cfg.vocab_size;
    let slots = 2usize;
    let kv_block_size = 16usize;
    let kv_blocks = slots
        * kv_positions_needed(prompt_len, max_new).div_ceil(kv_block_size);
    let server = Server::start(model, ServePolicy {
        slots,
        max_wait: Duration::from_millis(2),
        kv_block_size,
        kv_blocks,
        prefill_chunk: kv_block_size,
        route_density: 0.25,
        prefix_cache: false,
        max_queue: if shed { slots } else { 0 },
        mode: ServeMode::Continuous,
        shards: 1,
    });
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..n_requests {
        let prompt: Vec<u32> = (0..prompt_len)
            .map(|j| ((i * 131 + j * 31) % vocab) as u32)
            .collect();
        let params = SamplingParams {
            seed: i as u64,
            ..SamplingParams::greedy()
        };
        if shed {
            let deadline = if i % 4 == 0 {
                Instant::now() // already expired on arrival
            } else {
                Instant::now()
                    + Duration::from_secs_f64(deadline_ms / 1e3)
            };
            let opts = SubmitOptions {
                deadline: Some(deadline),
                max_queue_wait: Some(Duration::from_millis(2)),
            };
            match server.submit_opts(prompt, max_new, params, opts) {
                Ok((_, rx)) => rxs.push(rx),
                Err(SubmitError::Busy) => {} // shed at the boundary
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        } else {
            let (_, rx) = server
                .submit_sampled(prompt, max_new, params)
                .expect("request fits pool");
            rxs.push(rx);
        }
    }
    let mut metrics = ServeMetrics::default();
    for rx in rxs {
        metrics.record(rx.recv().expect("worker dropped"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    let good_toks: usize = metrics
        .completions
        .iter()
        .filter(|c| {
            c.finish == FinishReason::Length && c.total_ms <= deadline_ms
        })
        .map(|c| c.tokens.len() + c.prefill_tokens)
        .sum();
    let ttfts: Vec<f64> = metrics
        .completions
        .iter()
        .filter(|c| c.finish == FinishReason::Length)
        .map(|c| c.first_token_ms)
        .collect();
    let p99_ttft = if ttfts.is_empty() {
        0.0
    } else {
        repro::util::stats::percentile(&ttfts, 99.0)
    };
    let out = (good_toks as f64 / wall, p99_ttft, stats, ttfts.len());
    server.shutdown();
    out
}

/// Time a pure-decode loop at a fixed batch: `batch` slots prefilled
/// with `prompt_len` tokens, then `steps` greedy-feedback decode
/// iterations through one persistent `DecodeScratch` — the kernel-level
/// view of the skinny-batch fast path, with no scheduler noise.
/// `route_density > 0` enables batch-contextual routing at that
/// union-density threshold.  Returns (decode tokens/sec, the routing
/// dispatch counters for the timed steps only — warmup and prefill are
/// discarded).
fn decode_wave(
    model: &Model, batch: usize, prompt_len: usize, steps: usize,
    route_density: f32,
) -> (f64, RouteStats) {
    let block = 16usize;
    let warmup = 2usize;
    let positions = prompt_len + steps + warmup;
    let blocks = batch * positions.div_ceil(block);
    let mut cache = PagedKvCache::new(model, batch, blocks, block);
    for s in 0..batch {
        cache.reserve(s, positions).expect("bench pool sized for worst case");
    }
    let mut scratch = DecodeScratch::new(model, batch * prompt_len, batch);
    scratch.route.enabled = route_density > 0.0;
    scratch.route.max_density = route_density;
    let vocab = model.cfg.vocab_size;
    let prompts: Vec<Vec<u32>> = (0..batch)
        .map(|s| {
            (0..prompt_len)
                .map(|j| ((s * 131 + j * 31) % vocab) as u32)
                .collect()
        })
        .collect();
    let mut toks: Vec<(usize, [u32; 1])> = {
        let feeds: Vec<(usize, &[u32])> =
            prompts.iter().enumerate().map(|(s, p)| (s, &p[..])).collect();
        let l = model.prefill_decode_step_into(&mut cache, &feeds,
                                               &mut scratch);
        (0..batch).map(|s| (s, [argmax(l.row(s)) as u32])).collect()
    };
    let advance = |toks: &mut Vec<(usize, [u32; 1])>,
                   cache: &mut PagedKvCache,
                   scratch: &mut DecodeScratch| {
        let next: Vec<u32> = {
            let feeds: Vec<(usize, &[u32])> =
                toks.iter().map(|(s, t)| (*s, &t[..])).collect();
            let l = model.prefill_decode_step_into(cache, &feeds, scratch);
            (0..l.rows).map(|r| argmax(l.row(r)) as u32).collect()
        };
        for ((_, t), &n) in toks.iter_mut().zip(&next) {
            t[0] = n;
        }
    };
    // warm the pool (worker spawn, first-touch paging) off the clock,
    // then drop the prefill + warmup dispatch counts so the returned
    // stats cover exactly the timed steps
    for _ in 0..warmup {
        advance(&mut toks, &mut cache, &mut scratch);
    }
    let _ = scratch.route.stats.take();
    let t0 = Instant::now();
    for _ in 0..steps {
        advance(&mut toks, &mut cache, &mut scratch);
    }
    let tok_s = (batch * steps) as f64 / t0.elapsed().as_secs_f64();
    (tok_s, scratch.route.stats.take())
}

fn backend_label(backend: FfnBackend) -> &'static str {
    match backend {
        FfnBackend::Dense => "dense",
        FfnBackend::Twell => "twell",
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke");
    if let Some(i) = argv.iter().position(|a| a == "--threads") {
        let n: usize = argv
            .get(i + 1)
            .and_then(|v| v.parse().ok())
            .expect("--threads needs a positive integer");
        par::set_threads(n);
    }
    let threads = par::num_threads();
    let (n_requests, prompt_len, max_new) =
        if smoke { (6, 4, 4) } else { (32, 8, 16) };
    let kv_block_size = 16usize;
    let slot_sweep: &[usize] = if smoke { &[1, 4] } else { &[1, 4, 8, 16] };
    println!("== continuous-batching serve throughput ==");
    println!(
        "synthetic 4L d=128 f=352 model, nnz≈30; {n_requests} requests, \
         prompt {prompt_len}, max_new {max_new}, {threads} threads\n"
    );
    let mut table = Table::new(&[
        "backend", "slots", "tok/s", "p50 ms", "p95 ms", "ttft p50",
        "backfills",
    ]);
    let mut rows = Vec::new();
    for backend in [FfnBackend::Dense, FfnBackend::Twell] {
        let label = backend_label(backend);
        for &slots in slot_sweep {
            let (tok_s, p50, p95, ttft, stats) = run_wave(
                backend, 1, slots, n_requests, prompt_len, max_new,
                kv_block_size, kv_block_size, SamplingParams::greedy(),
            );
            let backfills = stats.backfilled;
            table.row(&[
                label.to_string(),
                slots.to_string(),
                format!("{tok_s:.0}"),
                format!("{p50:.1}"),
                format!("{p95:.1}"),
                format!("{ttft:.1}"),
                backfills.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("backend", Json::str(label)),
                ("slots", Json::Num(slots as f64)),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("prefill_chunk", Json::Num(kv_block_size as f64)),
                ("temperature", Json::Num(0.0)),
                ("top_p", Json::Num(1.0)),
                ("threads", Json::Num(threads as f64)),
                ("tok_s", Json::Num(tok_s)),
                ("p50_ms", Json::Num(p50)),
                ("p95_ms", Json::Num(p95)),
                ("first_token_ms", Json::Num(ttft)),
                ("backfills", Json::Num(backfills as f64)),
            ]));
        }
    }
    table.print();
    println!(
        "\nshape check: tokens/sec should rise monotonically 1 -> 8 \
         slots (batched decode amortizes the FFN kernels); p50 rises \
         slowly with slots while total wall time collapses."
    );

    // ---- TTFT vs prefill chunk: long prompts (4x the KV block) through
    // chunk 1 (the old token-by-token prefill baseline), one block per
    // step (the default), and whole-prompt chunks ------------------------
    let (ttft_requests, long_prompt, ttft_max_new, ttft_slots) = if smoke {
        (4usize, 4 * kv_block_size, 4usize, 2usize)
    } else {
        (16usize, 4 * kv_block_size, 8usize, 4usize)
    };
    println!(
        "\n== time-to-first-token vs prefill chunk ==\n\
         prompt {long_prompt} (4x the {kv_block_size}-position KV \
         block), {ttft_requests} requests, max_new {ttft_max_new}, \
         {ttft_slots} slots; chunk 1 is the single-token-prefill \
         baseline\n"
    );
    let mut ttft_table = Table::new(&[
        "backend", "chunk", "ttft p50 ms", "p50 ms", "tok/s",
    ]);
    for backend in [FfnBackend::Dense, FfnBackend::Twell] {
        let label = backend_label(backend);
        for &prefill_chunk in &[1usize, kv_block_size, long_prompt] {
            let (tok_s, p50, p95, ttft, stats) = run_wave(
                backend, 1, ttft_slots, ttft_requests, long_prompt,
                ttft_max_new, kv_block_size, prefill_chunk,
                SamplingParams::greedy(),
            );
            let backfills = stats.backfilled;
            ttft_table.row(&[
                label.to_string(),
                prefill_chunk.to_string(),
                format!("{ttft:.1}"),
                format!("{p50:.1}"),
                format!("{tok_s:.0}"),
            ]);
            // same row schema as the slot sweep above, so trajectory
            // tooling can index every row uniformly
            rows.push(Json::obj(vec![
                ("backend", Json::str(label)),
                ("slots", Json::Num(ttft_slots as f64)),
                ("prompt_len", Json::Num(long_prompt as f64)),
                ("prefill_chunk", Json::Num(prefill_chunk as f64)),
                ("temperature", Json::Num(0.0)),
                ("top_p", Json::Num(1.0)),
                ("threads", Json::Num(threads as f64)),
                ("tok_s", Json::Num(tok_s)),
                ("p50_ms", Json::Num(p50)),
                ("p95_ms", Json::Num(p95)),
                ("first_token_ms", Json::Num(ttft)),
                ("backfills", Json::Num(backfills as f64)),
            ]));
        }
    }
    ttft_table.print();
    println!(
        "\nshape check: ttft p50 should drop sharply from chunk 1 to \
         one block per step — prefill takes ceil(L / chunk) engine \
         iterations instead of L."
    );

    // ---- sampled decode: greedy argmax vs temperature 0.8 / top-p 0.95
    // per-request sampling — the processor pipeline (sort + softmax +
    // nucleus cut over the vocab) runs once per sampled token, so this
    // sweep prices stochastic decoding on the hot decode loop -----------
    let sample_slots = if smoke { 4usize } else { 8usize };
    println!(
        "\n== sampled decode: greedy vs t=0.8 top-p=0.95 ==\n\
         {n_requests} requests, prompt {prompt_len}, max_new \
         {max_new}, {sample_slots} slots; each request draws from its \
         own seeded RNG, so sampled traffic genuinely diverges\n"
    );
    let mut sample_table = Table::new(&[
        "backend", "sampling", "tok/s", "p50 ms", "p95 ms", "ttft p50",
    ]);
    let sweeps = [
        ("greedy", SamplingParams::greedy()),
        (
            "t=0.8 top-p=0.95",
            SamplingParams {
                temperature: 0.8,
                top_k: 0,
                top_p: 0.95,
                seed: 7,
            },
        ),
    ];
    for backend in [FfnBackend::Dense, FfnBackend::Twell] {
        let label = backend_label(backend);
        for (sampling, params) in sweeps {
            let (tok_s, p50, p95, ttft, stats) = run_wave(
                backend, 1, sample_slots, n_requests, prompt_len,
                max_new, kv_block_size, kv_block_size, params,
            );
            let backfills = stats.backfilled;
            sample_table.row(&[
                label.to_string(),
                sampling.to_string(),
                format!("{tok_s:.0}"),
                format!("{p50:.1}"),
                format!("{p95:.1}"),
                format!("{ttft:.1}"),
            ]);
            rows.push(Json::obj(vec![
                ("backend", Json::str(label)),
                ("slots", Json::Num(sample_slots as f64)),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("prefill_chunk", Json::Num(kv_block_size as f64)),
                ("temperature", Json::Num(params.temperature as f64)),
                ("top_p", Json::Num(params.top_p as f64)),
                ("threads", Json::Num(threads as f64)),
                ("tok_s", Json::Num(tok_s)),
                ("p50_ms", Json::Num(p50)),
                ("p95_ms", Json::Num(p95)),
                ("first_token_ms", Json::Num(ttft)),
                ("backfills", Json::Num(backfills as f64)),
            ]));
        }
    }
    sample_table.print();
    println!(
        "\nshape check: sampled decode should track greedy closely — \
         the pipeline is O(V log V) per token on a small vocab, so the \
         FFN still dominates; a large gap means the sampler is \
         allocating or sorting more than it should."
    );

    // ---- skinny-batch decode kernel sweep: the seed's row-parallel
    // dispatch (skinny kernels on one core) vs the pooled
    // column-parallel fast path, pure decode, no scheduler noise --------
    let decode_steps = if smoke { 6usize } else { 48usize };
    let decode_prompt = 4usize;
    println!(
        "\n== decode kernel sweep: seed row dispatch vs pooled \
         column-parallel ==\n\
         pure decode at batch 1/4/8/16, {decode_steps} timed steps, \
         greedy feedback, persistent scratch, {threads} threads\n"
    );
    let mut decode_table =
        Table::new(&["backend", "path", "batch", "decode tok/s"]);
    for backend in [FfnBackend::Dense, FfnBackend::Twell] {
        let label = backend_label(backend);
        let model = synthetic_model(4, 30.0, backend);
        for &batch in &[1usize, 4, 8, 16] {
            for (path, fast) in [("row-seed", false), ("col-pool", true)] {
                par::set_skinny_fast_path(fast);
                let (tok_s, _) = decode_wave(
                    &model, batch, decode_prompt, decode_steps, 0.0,
                );
                decode_table.row(&[
                    label.to_string(),
                    path.to_string(),
                    batch.to_string(),
                    format!("{tok_s:.0}"),
                ]);
                rows.push(Json::obj(vec![
                    ("section", Json::str("decode_kernel")),
                    ("backend", Json::str(label)),
                    ("path", Json::str(path)),
                    ("batch", Json::Num(batch as f64)),
                    ("threads", Json::Num(threads as f64)),
                    ("decode_tok_s", Json::Num(tok_s)),
                ]));
            }
        }
    }
    par::set_skinny_fast_path(true);
    decode_table.print();
    println!(
        "\nshape check: col-pool should beat row-seed at every batch \
         <= 16 — the seed dispatch ran every decode-shaped kernel \
         (fused QKV, output projection, TwELL gate + fused FFN, vocab \
         logits) on a single core."
    );

    // ---- decode routing sweep: batch-contextual union-gathered FFN
    // (threshold 1.0, so every pure-decode step routes) vs the
    // unrouted twell row path vs the dense backend, at ~99% sparsity
    // (nnz ≈ 3.5 of f=352) where the batch union stays skinny even at
    // batch 64 --------------------------------------------------------
    println!(
        "\n== decode routing sweep: routed union-gather vs twell row \
         vs dense ==\n\
         pure decode at batch 1..64, nnz≈3.5 (~99% sparse), \
         {decode_steps} timed steps, {threads} threads; \
         union density is measured on the routed probe\n"
    );
    let mut route_table = Table::new(&[
        "path", "batch", "decode tok/s", "union density", "dispatch",
    ]);
    let model99_twell = synthetic_model(4, 3.5, FfnBackend::Twell);
    let model99_dense = synthetic_model(4, 3.5, FfnBackend::Dense);
    for &batch in &[1usize, 4, 8, 16, 32, 64] {
        // routed probe first: it measures the batch-union density that
        // annotates all three rows at this batch size
        let (tok_r, st_r) = decode_wave(
            &model99_twell, batch, decode_prompt, decode_steps, 1.0,
        );
        let union_density = st_r.mean_density();
        let (tok_t, st_t) = decode_wave(
            &model99_twell, batch, decode_prompt, decode_steps, 0.0,
        );
        let (tok_d, st_d) = decode_wave(
            &model99_dense, batch, decode_prompt, decode_steps, 0.0,
        );
        let runs = [
            ("twell", "routed", tok_r, st_r.dominant()),
            ("twell", "twell-row", tok_t, st_t.dominant()),
            ("dense", "dense", tok_d, st_d.dominant()),
        ];
        for (label, path, tok_s, dispatch) in runs {
            route_table.row(&[
                path.to_string(),
                batch.to_string(),
                format!("{tok_s:.0}"),
                format!("{union_density:.3}"),
                dispatch.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("section", Json::str("decode_routing")),
                ("backend", Json::str(label)),
                ("path", Json::str(path)),
                ("batch", Json::Num(batch as f64)),
                ("threads", Json::Num(threads as f64)),
                ("decode_tok_s", Json::Num(tok_s)),
                ("union_density", Json::Num(union_density)),
                ("dispatch", Json::str(dispatch)),
            ]));
        }
    }
    route_table.print();
    println!(
        "\nshape check: at ~99% sparsity the batch union grows \
         sub-linearly with batch (active sets overlap), so the routed \
         path's skinny GEMMs should beat the per-row twell walk as \
         batch grows and beat dense everywhere the union stays far \
         below f."
    );

    // ---- shard sweep: N engine shards behind one admission queue,
    // slots per shard, the total thread budget split evenly across
    // shards (every shard's kernel steps still serialize on the one
    // process-global pool, so this measures placement + admission
    // overhead, not parallel model speedup) -----------------------------
    let shard_slot_sweep: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let total_threads = threads;
    println!(
        "\n== shard sweep: 1/2/4 engine shards, one admission queue \
         ==\n\
         {n_requests} requests, prompt {prompt_len}, max_new \
         {max_new}; slots are per shard and the {total_threads}-thread \
         budget is split evenly across shards\n"
    );
    let mut shard_table = Table::new(&[
        "backend", "shards", "slots", "tok/s", "p50 ms", "ttft p50",
        "queue peak",
    ]);
    for backend in [FfnBackend::Dense, FfnBackend::Twell] {
        let label = backend_label(backend);
        for &shards in &[1usize, 2, 4] {
            par::set_threads(
                par::threads_per_shard(total_threads, shards),
            );
            for &slots in shard_slot_sweep {
                let (tok_s, p50, p95, ttft, stats) = run_wave(
                    backend, shards, slots, n_requests, prompt_len,
                    max_new, kv_block_size, kv_block_size,
                    SamplingParams::greedy(),
                );
                shard_table.row(&[
                    label.to_string(),
                    shards.to_string(),
                    slots.to_string(),
                    format!("{tok_s:.0}"),
                    format!("{p50:.1}"),
                    format!("{ttft:.1}"),
                    stats.queue_peak.to_string(),
                ]);
                rows.push(Json::obj(vec![
                    ("section", Json::str("shard_sweep")),
                    ("backend", Json::str(label)),
                    ("shards", Json::Num(shards as f64)),
                    ("slots", Json::Num(slots as f64)),
                    ("threads", Json::Num(par::num_threads() as f64)),
                    ("prompt_len", Json::Num(prompt_len as f64)),
                    ("tok_s", Json::Num(tok_s)),
                    ("p50_ms", Json::Num(p50)),
                    ("p95_ms", Json::Num(p95)),
                    ("first_token_ms", Json::Num(ttft)),
                    ("queue_peak", Json::Num(stats.queue_peak as f64)),
                ]));
            }
        }
    }
    par::set_threads(total_threads);
    shard_table.print();
    println!(
        "\nshape check: shards > 1 should hold tok/s near the 1-shard \
         line (kernels serialize on the shared pool either way) while \
         queue peak shrinks — more shards drain the admission queue \
         faster."
    );

    // ---- prefix-cache sweep: 80% of requests share a long system
    // prompt; copy-on-write sharing should collapse TTFT (sharers skip
    // the cached prefix blocks) and the peak block footprint, while
    // greedy streams stay bit-identical with sharing off ----------------
    let (pc_requests, pc_prefix, pc_tail, pc_max_new, pc_slots) = if smoke {
        (10usize, 128usize, 4usize, 4usize, 4usize)
    } else {
        (25usize, 256usize, 8usize, 8usize, 4usize)
    };
    println!(
        "\n== prefix-cache sweep: 80% of requests share a \
         {pc_prefix}-token system prompt ==\n\
         {pc_requests} requests, tail {pc_tail}, max_new {pc_max_new}, \
         {pc_slots} slots, twell backend, greedy; one warm-up request \
         seeds the cache off the clock\n"
    );
    let mut pc_table = Table::new(&[
        "prefix cache", "tok/s", "p50 ms", "ttft p50", "hits",
        "blocks shared", "cow copies", "peak KV blocks",
    ]);
    let mut pc_streams: Vec<Vec<Vec<u32>>> = Vec::new();
    for on in [true, false] {
        let (tok_s, p50, ttft, stats, streams) = run_prefix_wave(
            on, pc_requests, pc_prefix, pc_tail, pc_max_new, pc_slots,
        );
        pc_streams.push(streams);
        let prefix = if on { "on" } else { "off" };
        pc_table.row(&[
            prefix.to_string(),
            format!("{tok_s:.0}"),
            format!("{p50:.1}"),
            format!("{ttft:.1}"),
            stats.prefix_hits.to_string(),
            stats.prefix_blocks_shared.to_string(),
            stats.cow_copies.to_string(),
            stats.kv_blocks_peak.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("section", Json::str("prefix_cache")),
            ("backend", Json::str("twell")),
            ("prefix", Json::str(prefix)),
            ("requests", Json::Num(pc_requests as f64)),
            ("prefix_len", Json::Num(pc_prefix as f64)),
            ("threads", Json::Num(threads as f64)),
            ("tok_s", Json::Num(tok_s)),
            ("p50_ms", Json::Num(p50)),
            ("first_token_ms", Json::Num(ttft)),
            ("prefix_hits", Json::Num(stats.prefix_hits as f64)),
            ("prefix_blocks_shared",
             Json::Num(stats.prefix_blocks_shared as f64)),
            ("cow_copies", Json::Num(stats.cow_copies as f64)),
            ("kv_blocks_peak", Json::Num(stats.kv_blocks_peak as f64)),
        ]));
    }
    assert_eq!(
        pc_streams[0], pc_streams[1],
        "prefix caching changed a decoded stream — placement must \
         never perturb tokens"
    );
    pc_table.print();
    println!(
        "\nshape check: ttft p50 and peak KV blocks should both drop \
         sharply with the cache on — sharers skip ~{} cached blocks of \
         prefill and the pool stores the hot prefix once; streams are \
         asserted bit-identical either way.",
        pc_prefix / kv_block_size
    );

    // ---- overload sweep: a burst tens of requests deep at a 2-slot
    // engine, with the QoS layer (bounded queue + bounded submit
    // wait + per-request deadlines) on vs off.  Goodput counts only
    // within-deadline completions, so "serve everyone, late" loses to
    // "serve fewer, on time" --------------------------------------------
    let (ov_requests, ov_prompt, ov_max_new) =
        if smoke { (24usize, 4usize, 4usize) } else { (48usize, 8, 16) };
    // calibrate the deadline budget off an uncontended request, so the
    // sweep's shape survives machine-speed differences: an accepted
    // request at queue depth <= max_queue always fits the budget, a
    // request queued tens deep never does
    let (_, single_ms, _, _, _) = run_wave(
        FfnBackend::Twell, 1, 1, 1, ov_prompt, ov_max_new,
        kv_block_size, kv_block_size, SamplingParams::greedy(),
    );
    let ov_deadline_ms = (4.0 * single_ms).max(2.0);
    println!(
        "\n== overload sweep: load shedding on vs off ==\n\
         {ov_requests} requests burst at a 2-slot engine, prompt \
         {ov_prompt}, max_new {ov_max_new}, deadline {ov_deadline_ms:.1} \
         ms (4x an uncontended request); shed=on bounds the queue at 2, \
         caps the submit wait at 2 ms, and every 4th request arrives \
         already expired\n"
    );
    let mut ov_table = Table::new(&[
        "shed", "goodput tok/s", "p99 ttft ms", "served",
        "shed busy", "shed deadline", "rejections", "aborts",
    ]);
    for shed in [true, false] {
        let (goodput, p99_ttft, stats, served) = run_overload_wave(
            shed, ov_requests, ov_prompt, ov_max_new, ov_deadline_ms,
        );
        let label = if shed { "on" } else { "off" };
        ov_table.row(&[
            label.to_string(),
            format!("{goodput:.0}"),
            format!("{p99_ttft:.1}"),
            served.to_string(),
            stats.shed_busy.to_string(),
            stats.shed_deadline.to_string(),
            stats.queue_rejections.to_string(),
            stats.deadline_aborts.to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("section", Json::str("overload")),
            ("backend", Json::str("twell")),
            ("shed", Json::str(label)),
            ("requests", Json::Num(ov_requests as f64)),
            ("deadline_ms", Json::Num(ov_deadline_ms)),
            ("threads", Json::Num(threads as f64)),
            ("goodput_tok_s", Json::Num(goodput)),
            ("p99_ttft_ms", Json::Num(p99_ttft)),
            ("served", Json::Num(served as f64)),
            ("shed_busy", Json::Num(stats.shed_busy as f64)),
            ("shed_deadline", Json::Num(stats.shed_deadline as f64)),
            ("queue_rejections",
             Json::Num(stats.queue_rejections as f64)),
            ("deadline_aborts",
             Json::Num(stats.deadline_aborts as f64)),
            ("shard_restarts", Json::Num(stats.shard_restarts as f64)),
        ]));
    }
    ov_table.print();
    println!(
        "\nshape check: with shedding on, goodput and p99 TTFT should \
         both beat the unbounded run — backpressure keeps queue time \
         off the clock of every accepted request, while the unbounded \
         queue serves everyone but serves the tail hopelessly late."
    );

    let report = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("rows", Json::Arr(rows)),
    ]);
    println!("{report}");
    // persist at the repo root so the perf trajectory can track the
    // numbers across PRs, not just scrape stdout
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("BENCH_serve_throughput.json");
    match report.write_file(&out) {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
}
