//! EXP-F1 (figure 1 + section 3.2 motivation): sparse-format comparison.
//!
//! Regenerates two things the paper argues in prose:
//!   1. storage bytes of dense vs ELL vs TwELL vs hybrid at matched
//!      sparsity (figure 1's layouts),
//!   2. the *materialization cost*: classic ELL needs a full second pass
//!      over a dense h_g, while TwELL packs inside the gate matmul's
//!      epilogue — we time exactly that difference.
//!
//! Dims are the paper's H100 shapes scaled 1/8 for the single-core
//! testbed; ratios are what matters (DESIGN.md section 1).

use repro::metrics::memory;
use repro::sparse::ell::EllMatrix;
use repro::sparse::ffn::synth_sparse_ffn;
use repro::sparse::twell::{gate_matmul_twell, TwellMatrix};
use repro::sparse::dense;
use repro::sparse::hybrid::HybridMatrix;
use repro::util::bench::{fmt_time, Bencher, Table};

fn main() {
    let (m, k, n) = (256, 256, 704); // paper: 2048 x 2048 x 5632
    let tile_n = 32;
    println!("== figure 1: format storage + materialization cost ==");
    println!("dims: M={m} K={k} N={n} (paper dims / 8)\n");

    let mut table = Table::new(&[
        "avg nnz/row", "dense B", "ELL B", "TwELL B", "hybrid B",
        "gate+ELL pack", "gate+TwELL epilogue", "fusion speedup",
    ]);
    let bencher = Bencher::quick();
    for target_nnz in [700.0, 352.0, 88.0, 30.0, 8.0] {
        let comp = if target_nnz > 176.0 { 1 } else { 4 };
        let (w, x) = synth_sparse_ffn(m, k, n, target_nnz, 42, tile_n, comp,
                                      128, 0.125);
        let tw = gate_matmul_twell(&x, &w.wg, tile_n, comp);
        let hg = dense::matmul_relu(&x, &w.wg);
        let ell = EllMatrix::from_dense(&hg);
        let (hyb, _, _) = HybridMatrix::from_twell(&tw, 128, m / 8);

        // classic path: dense gate matmul THEN a separate ELL pack pass
        let r_ell = bencher.run("ell", || {
            let hg = dense::matmul_relu(&x, &w.wg);
            let e = EllMatrix::from_dense(&hg);
            std::hint::black_box(e.width);
        });
        // paper path: TwELL materialized in the epilogue, no second pass
        let r_tw = bencher.run("twell", || {
            let t = gate_matmul_twell(&x, &w.wg, tile_n, comp);
            std::hint::black_box(t.total_nnz());
        });
        table.row(&[
            format!("{:.1}", tw.avg_nnz_per_row()),
            memory::dense_bytes(m, n, 4).to_string(),
            ell.bytes().to_string(),
            tw.bytes().to_string(),
            hyb.bytes().to_string(),
            fmt_time(r_ell.median_s),
            fmt_time(r_tw.median_s),
            format!("{:.2}x", r_ell.median_s / r_tw.median_s),
        ]);
        let _ = TwellMatrix::from_dense(&hg, tile_n, comp);
    }
    table.print();
    println!(
        "\nshape check vs paper: TwELL ~N/C words/row regardless of max \
         nnz; ELL pays the global max; hybrid pays width+tail; epilogue \
         fusion beats matmul-then-pack at every sparsity."
    );
}
