//! Table 1 "forward execution" end-to-end: whole-transformer prefill
//! throughput (input tokens/ms), dense FFN backend vs the TwELL pipeline,
//! across the model-scale family — on a trained checkpoint when one
//! exists (runs/e2e_s) and otherwise on a synthetic model whose gate bias
//! is calibrated to the paper's sparsity.

use repro::config::default_paths;
use repro::coordinator::ckpt::Checkpoint;
use repro::model::{FfnBackend, Model};
use repro::sparse::ffn::synth_sparse_ffn;
use repro::tensor::Mat;
use repro::util::bench::{Bencher, Table};
use repro::util::rng::Pcg32;

fn synthetic_model(layers: usize, target_nnz: f64) -> Model {
    use repro::config::ModelConfig;
    use repro::model::Layer;
    let d = 128;
    let f = 352;
    let cfg = ModelConfig {
        name: format!("synth{layers}"),
        vocab_size: 512,
        d_model: d,
        n_layers: layers,
        n_heads: 4,
        d_ff: f,
        gated: true,
        activation: "relu".into(),
        rope_theta: 1e4,
        rmsnorm_eps: 1e-5,
        init_std: 0.02,
        train_batch: 16,
        seq_len: 128,
        score_batch: 32,
        twell_tile_n: 32,
        twell_comp: 4,
        ell_width: 128,
        dense_backup_frac: 0.125,
    };
    let mut rng = Pcg32::seeded(5);
    let layers_v = (0..layers)
        .map(|li| {
            let (ffn, _) = synth_sparse_ffn(
                64, d, f, target_nnz, 100 + li as u64, 32, 4, 128, 0.125,
            );
            Layer::new(
                vec![1.0; d],
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                Mat::randn(d, d, 0.05, &mut rng),
                vec![1.0; d],
                ffn,
            )
        })
        .collect();
    let embed = Mat::randn(cfg.vocab_size, d, 0.05, &mut rng);
    Model::assemble(cfg, embed, layers_v, vec![1.0; d], FfnBackend::Dense, 4)
}

fn bench_model(label: &str, mut model: Model, table: &mut Table) {
    let (batch, seq) = (8, 64);
    let tokens: Vec<u32> = (0..batch * seq)
        .map(|i| (i * 31 % model.cfg.vocab_size) as u32)
        .collect();
    let bencher = Bencher::quick();
    model.backend = FfnBackend::Dense;
    let rd = bencher.run("dense", || {
        std::hint::black_box(model.forward(&tokens, batch, seq).0.data[0]);
    });
    model.backend = FfnBackend::Twell;
    let mut nnz = 0f64;
    let rs = bencher.run("twell", || {
        let (l, st) = model.forward(&tokens, batch, seq);
        nnz = (0..model.cfg.n_layers).map(|i| st.avg_nnz(i)).sum::<f64>()
            / model.cfg.n_layers as f64;
        std::hint::black_box(l.data[0]);
    });
    let toks = (batch * seq) as f64;
    table.row(&[
        label.to_string(),
        format!("{:.1}", nnz),
        format!("{:.1}", toks / (rd.median_s * 1e3)),
        format!("{:.1}", toks / (rs.median_s * 1e3)),
        format!("{:+.1}%", 100.0 * (rd.median_s / rs.median_s - 1.0)),
    ]);
}

fn main() {
    println!("== table 1 (forward execution): end-to-end transformer ==\n");
    let mut table = Table::new(&[
        "model", "avg nnz", "dense tok/ms", "twell tok/ms", "speedup",
    ]);
    // trained checkpoint if the E2E example has run
    let ckpt = default_paths().run_dir("e2e_s").join("checkpoint.bin");
    if ckpt.exists() {
        if let Ok(ck) = Checkpoint::load(&ckpt) {
            if let Ok(model) = Model::from_checkpoint(&ck, FfnBackend::Dense)
            {
                bench_model("trained e2e_s", model, &mut table);
            }
        }
    }
    // scale family at the paper's recommended sparsity (nnz ~30) and at
    // near-dense (the figure-10 negative-contribution case)
    for layers in [2usize, 4, 6, 8] {
        bench_model(
            &format!("synth {layers}L sparse"),
            synthetic_model(layers, 30.0),
            &mut table,
        );
    }
    bench_model("synth 4L near-dense", synthetic_model(4, 320.0), &mut table);
    table.print();
    println!(
        "\nshape check vs paper table 1: sparse wins at every scale and \
         the relative gain grows with depth (FFN share grows); the \
         near-dense model shows the figure-10 slowdown."
    );
}
